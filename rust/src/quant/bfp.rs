//! Block-Floating-Point (MSFP) fake quantization — rust mirror of
//! `python/compile/kernels/bfp.py`.
//!
//! A tensor is viewed as rows of `inner` contiguous elements (the last
//! row may be ragged — shorter than `inner`); each row is split into
//! boxes of [`BOX`] (16) elements (the last box of a row may be short —
//! identical to the kernel's zero-padding because pad zeros never change
//! a box max). Per box: shared exponent from the box |max|, then sign +
//! (m-1)-bit magnitude per element.
//!
//! Non-finite semantics are the per-box analogue of the fixed kernel's
//! (see `fixed.rs` / the `quant` module docs): the box exponent comes
//! from the finite FTZ'd box max, NaN propagates — even out of an
//! all-NaN box, whose other mass flushes to zero — and ±inf clamp to
//! the box max magnitude.

use super::fixed::fill_zero_grid;
use super::{ftz, quant_grid, BOX, PASSTHROUGH_BITS};

/// Quantize `x` in place. `inner` is the length of the minor (last)
/// axis; a trailing partial row (`x.len() % inner != 0`) is quantized
/// as its own (ragged) row.
pub fn bfp_quantize_into(x: &mut [f32], inner: usize, mbits: f32) {
    assert!(inner > 0, "inner must be >= 1");
    if mbits >= PASSTHROUGH_BITS {
        return;
    }
    for row in x.chunks_mut(inner) {
        for boxed in row.chunks_mut(BOX) {
            quantize_box(boxed, mbits);
        }
    }
}

/// Out-of-place variant.
pub fn bfp_quantize(x: &[f32], inner: usize, mbits: f32) -> Vec<f32> {
    let mut out = x.to_vec();
    bfp_quantize_into(&mut out, inner, mbits);
    out
}

#[inline]
fn quantize_box(boxed: &mut [f32], m: f32) {
    // FTZ to match the XLA artifacts (subnormals read as zero there).
    let amax = boxed.iter().fold(0.0f32, |a, &v| a.max(ftz(v.abs())));
    if amax <= 0.0 {
        // Degenerate grid: zeros/subnormals flush, NaN propagates.
        fill_zero_grid(boxed);
        return;
    }
    // Hoist the box constants out of the element loop (§Perf: computing
    // step/maxmag per element cost ~2.4x throughput); the element rule
    // stays identical to quantize_with_exponent.
    let (_, step, maxmag) = quant_grid(amax, m);
    for v in boxed.iter_mut() {
        *v = (ftz(*v) / step).round_ties_even().clamp(-maxmag, maxmag) * step;
    }
}

/// Per-box statistics used by the cost model's error analysis and the
/// ablation benches: (shared exponent, quantization step, max magnitude).
///
/// Must agree exactly with `quantize_box`: the box max is read through
/// [`ftz`] (subnormal magnitudes are invisible to the kernels) and the
/// step exponent is clamped to the normal range, or the reported
/// (exponent, step) would disagree with the actual grid on
/// subnormal-heavy boxes.
pub fn bfp_dequantize_box_stats(boxed: &[f32], mbits: f32) -> (i32, f32, f32) {
    let amax = boxed.iter().fold(0.0f32, |a, &v| a.max(ftz(v.abs())));
    quant_grid(amax, mbits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{floor_log2, EXP_MIN};
    use crate::util::prop::{gen_f32s, Prop};
    use crate::util::rng::Pcg32;

    #[test]
    fn passthrough_at_25_bits() {
        let x = vec![1.123f32, -0.004, 7e8, 3e-9];
        assert_eq!(bfp_quantize(&x, 4, 25.0), x);
        assert_eq!(bfp_quantize(&x, 4, 32.0), x);
    }

    #[test]
    fn zero_box_stays_zero() {
        let x = vec![0.0f32; 32];
        assert_eq!(bfp_quantize(&x, 32, 4.0), x);
    }

    #[test]
    fn known_values_m4() {
        // One box: amax = 1.0 -> e = 0, step = 2^-2 = 0.25, maxmag 7.
        let x = vec![1.0f32, 0.3, -0.6, 0.125, 0.0, 0.0, 0.0, 0.0,
                     0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let q = bfp_quantize(&x, 16, 4.0);
        assert_eq!(q[0], 1.0);
        assert_eq!(q[1], 0.25);
        assert_eq!(q[2], -0.5); // -2.4 rounds to -2
        assert_eq!(q[3], 0.0); // 0.5 ties to even -> 0
    }

    #[test]
    fn boxes_have_independent_exponents() {
        // Box 1 huge, box 2 tiny: per-box scaling keeps the tiny box alive.
        let mut x = vec![0.0f32; 32];
        x[..16].fill(1000.0);
        x[16..].fill(0.001);
        let q = bfp_quantize(&x, 32, 4.0);
        assert!((q[20] - 0.001).abs() / 0.001 < 0.25, "small box lost: {}", q[20]);
    }

    #[test]
    fn short_final_box_matches_zero_padding() {
        // inner=24 -> boxes of 16 and 8; quantizing the 8 with 8 zeros
        // appended must give identical results.
        let mut rng = Pcg32::new(11);
        let x = gen_f32s(&mut rng, 24, 6.0);
        let q_short = bfp_quantize(&x, 24, 4.0);
        let mut padded = x.clone();
        padded.extend_from_slice(&[0.0; 8]);
        let q_pad = bfp_quantize(&padded, 32, 4.0);
        assert_eq!(&q_short[16..24], &q_pad[16..24]);
    }

    #[test]
    fn idempotent_property() {
        Prop::new("bfp quantization is idempotent").cases(60).run(
            |rng, size| {
                let len = 16 * (1 + size as usize / 20);
                (gen_f32s(rng, len, 12.0), [2.0f32, 4.0, 8.0, 16.0][rng.below(4) as usize])
            },
            |(x, m)| {
                let q1 = bfp_quantize(x, x.len(), *m);
                let q2 = bfp_quantize(&q1, x.len(), *m);
                if q1 == q2 {
                    Ok(())
                } else {
                    Err("q(q(x)) != q(x)".into())
                }
            },
        );
    }

    #[test]
    fn error_bounded_by_step_property() {
        Prop::new("bfp error <= step/2 for unclamped values").cases(60).run(
            |rng, size| (gen_f32s(rng, 16 * (1 + size as usize / 30), 8.0), 2.0 + rng.below(14) as f32),
            |(x, m)| {
                let q = bfp_quantize(x, x.len(), *m);
                for (boxed, qboxed) in x.chunks(16).zip(q.chunks(16)) {
                    let (_, step, maxmag) = bfp_dequantize_box_stats(boxed, *m);
                    for (&xi, &qi) in boxed.iter().zip(qboxed) {
                        let clamped = (xi / step).abs() > maxmag;
                        if !clamped && (qi - xi).abs() > step / 2.0 + step * 1e-6 {
                            return Err(format!("|q-x|={} > step/2={}", (qi - xi).abs(), step / 2.0));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn monotone_error_in_bits_property() {
        Prop::new("wider mantissa never increases total error").cases(40).run(
            |rng, size| gen_f32s(rng, 16 * (1 + size as usize / 25), 6.0),
            |x| {
                let err = |m: f32| {
                    bfp_quantize(x, x.len(), m)
                        .iter()
                        .zip(x)
                        .map(|(q, x)| ((q - x) as f64).abs())
                        .sum::<f64>()
                };
                let errs: Vec<f64> = [2.0f32, 4.0, 8.0, 16.0, 24.0].iter().map(|&m| err(m)).collect();
                for w in errs.windows(2) {
                    if w[1] > w[0] * 1.0000001 + 1e-12 {
                        return Err(format!("error increased with bits: {errs:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn box_stats_agree_with_quantizer_on_subnormal_boxes() {
        // A box whose max is subnormal: quantize_box sees amax = 0 (FTZ)
        // and zero-fills; the stats must report the same degenerate grid
        // (e = EXP_MIN after clamping the -127 zero exponent).
        let sub = f32::MIN_POSITIVE / 4.0;
        let boxed = vec![sub; 16];
        let (e, step, _) = bfp_dequantize_box_stats(&boxed, 4.0);
        assert_eq!(e, EXP_MIN, "FTZ'd box max must read as zero");
        let q = bfp_quantize(&boxed, 16, 4.0);
        assert_eq!(q, vec![0.0; 16]);
        // The reported step must itself be a normal f32 (clamped
        // exponent), exactly like the step quantize_box divides by.
        assert!(step >= f32::MIN_POSITIVE, "step {step} flushed under FTZ");
        // And on a mixed normal/subnormal box, the stats must use the
        // FTZ'd max: the subnormal entries cannot raise the exponent.
        let mut mixed = vec![0.0f32; 16];
        mixed[0] = 0.5;
        mixed[1] = sub;
        let (e2, step2, maxmag) = bfp_dequantize_box_stats(&mixed, 4.0);
        assert_eq!(e2, floor_log2(0.5));
        let q2 = bfp_quantize(&mixed, 16, 4.0);
        // Reconstruct element 0 from the reported grid.
        assert_eq!(q2[0], ((0.5 / step2).round_ties_even()).clamp(-maxmag, maxmag) * step2);
    }

    #[test]
    fn ragged_trailing_row_quantizes_as_its_own_row() {
        // len not a multiple of inner: the tail is a short row whose
        // boxes restart (they never continue the previous row's box).
        let mut rng = Pcg32::new(21);
        let x = gen_f32s(&mut rng, 2 * 24 + 10, 6.0);
        let q = bfp_quantize(&x, 24, 4.0);
        // Rows 0/1 match quantizing them alone; the 10-elem tail too.
        assert_eq!(&q[..48], bfp_quantize(&x[..48], 24, 4.0).as_slice());
        assert_eq!(&q[48..], bfp_quantize(&x[48..], 10, 4.0).as_slice());
    }

    #[test]
    fn nan_box_semantics_pinned() {
        // An all-NaN box keeps its NaNs; its neighbors are unaffected.
        let mut x = vec![1.0f32; 32];
        x[..16].fill(f32::NAN);
        let q = bfp_quantize(&x, 32, 4.0);
        assert!(q[..16].iter().all(|v| v.is_nan()), "all-NaN box must stay NaN");
        assert_eq!(&q[16..], &[1.0; 16]);
        // NaN mixed into a live box rides through; ±inf clamp per box.
        let mut y = vec![0.5f32; 16];
        y[0] = f32::NAN;
        y[1] = f32::INFINITY;
        let q = bfp_quantize(&y, 16, 4.0);
        assert!(q[0].is_nan());
        assert!(q[1].is_finite() && q[1] > 0.0, "inf clamps to the box max: {}", q[1]);
        // Like any huge outlier, inf blows up the box exponent and the
        // finite tail flushes — the heavy-tail failure mode, not a bug.
        assert_eq!(q[2], 0.0);
    }

    #[test]
    fn sign_preserved() {
        let mut rng = Pcg32::new(3);
        let x = gen_f32s(&mut rng, 256, 10.0);
        let q = bfp_quantize(&x, 16, 4.0);
        for (&xi, &qi) in x.iter().zip(&q) {
            assert!(qi == 0.0 || qi.signum() == xi.signum(), "sign flip: {xi} -> {qi}");
        }
    }
}
