//! Mini-criterion: a bench harness for `harness = false` bench targets
//! (criterion is unavailable offline).
//!
//! Provides warmup, adaptive iteration counts, robust statistics and a
//! compact report. Used by every `rust/benches/*.rs` target, which in
//! turn regenerate the paper's tables (the "benchmark" for a cost-model
//! table is its generation + consistency checks; the hot-path benches
//! time real code).

pub mod gate;

use std::time::{Duration, Instant};

use crate::util::stats;

/// One timed benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>10} ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            format!("±{}", fmt_ns(self.stddev_ns)),
            self.iters
        )
    }

    /// Throughput helper: elements per second given elements per iter.
    pub fn throughput(&self, elems_per_iter: f64) -> f64 {
        elems_per_iter / (self.mean_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner configuration.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end benches (PJRT steps).
    pub fn slow() -> Self {
        Bencher {
            warmup: Duration::from_millis(500),
            measure: Duration::from_secs(3),
            min_iters: 3,
            max_iters: 1_000,
        }
    }

    /// Time `f`, returning robust stats over per-iteration samples.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + estimate cost.
        let wstart = Instant::now();
        let mut wi = 0u64;
        while wstart.elapsed() < self.warmup || wi < 3 {
            f();
            wi += 1;
        }
        let per_iter = wstart.elapsed().as_secs_f64() / wi as f64;
        let target =
            ((self.measure.as_secs_f64() / per_iter.max(1e-9)) as u64).clamp(self.min_iters, self.max_iters);

        // Sample in batches so Instant overhead stays negligible.
        let batch = (target / 50).max(1);
        let mut samples = Vec::new();
        let mut done = 0u64;
        while done < target {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = s.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            done += batch;
        }
        BenchResult {
            name: name.to_string(),
            iters: done,
            mean_ns: stats::mean(&samples),
            median_ns: stats::median(&samples),
            stddev_ns: stats::stddev(&samples),
            min_ns: stats::min(&samples),
            max_ns: stats::max(&samples),
        }
    }
}

/// Standard bench-report header used by all bench targets. Status
/// decoration, so it goes through the leveled logger — `--quiet` /
/// `DSQ_LOG=error` silences it along with the rest of the run banter.
pub fn header(title: &str) {
    crate::info!("=== {title} ===");
    crate::info!("{:<44} {:>12} {:>12} {:>10}", "benchmark", "median", "mean", "stddev");
    crate::info!("{}", "-".repeat(84));
}

/// Machine-readable bench report (ROADMAP track 3b): results collected
/// during a run and serialized as `BENCH_<name>.json` at the repo root,
/// so successive runs leave a comparable perf trajectory instead of
/// scrollback. Hand-rolled JSON (no serde offline), same convention as
/// the stash store's `stash.json` index.
pub struct JsonReport {
    name: String,
    profile: String,
    entries: Vec<String>,
}

impl JsonReport {
    /// `name` becomes the file name (`BENCH_<name>.json`); `profile` is
    /// recorded so smoke and full runs are never compared to each other.
    pub fn new(name: &str, profile: &str) -> JsonReport {
        JsonReport { name: name.to_string(), profile: profile.to_string(), entries: Vec::new() }
    }

    /// Record one result; `elems_per_iter` adds a derived
    /// elements-per-second throughput field when meaningful.
    pub fn push(&mut self, r: &BenchResult, elems_per_iter: Option<f64>) {
        let mut e = format!(
            "{{\"name\": {}, \"iters\": {}, \"median_ns\": {:.1}, \"mean_ns\": {:.1}, \
             \"stddev_ns\": {:.1}, \"min_ns\": {:.1}, \"max_ns\": {:.1}",
            json_str(&r.name),
            r.iters,
            r.median_ns,
            r.mean_ns,
            r.stddev_ns,
            r.min_ns,
            r.max_ns
        );
        if let Some(n) = elems_per_iter {
            e.push_str(&format!(", \"elem_per_s\": {:.0}", r.throughput(n)));
        }
        e.push('}');
        self.entries.push(e);
    }

    /// Serialize the report.
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"bench\": {},\n  \"profile\": {},\n  \"results\": [\n    {}\n  ]\n}}\n",
            json_str(&self.name),
            json_str(&self.profile),
            self.entries.join(",\n    ")
        )
    }

    /// Write `BENCH_<name>.json` at the repo root (found by walking up
    /// from the current directory — `cargo bench` runs in `rust/`).
    /// Returns the path written.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let cwd = std::env::current_dir()?;
        let root = crate::analysis::find_root(&cwd).unwrap_or(cwd);
        let path = root.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

/// Minimal JSON string escape (quotes and backslashes; bench names are
/// plain ASCII).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 100_000,
        };
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn json_report_escapes_and_derives_throughput() {
        let mut j = JsonReport::new("quantizer", "smoke");
        let r = BenchResult {
            name: "enc \"x\"".into(),
            iters: 3,
            mean_ns: 1e9,
            median_ns: 1e9,
            stddev_ns: 0.0,
            min_ns: 1e9,
            max_ns: 1e9,
        };
        j.push(&r, Some(500.0));
        j.push(&r, None);
        let s = j.to_json();
        assert!(s.contains("\"bench\": \"quantizer\""));
        assert!(s.contains("\"profile\": \"smoke\""));
        assert!(s.contains("\\\"x\\\""));
        assert!(s.contains("\"elem_per_s\": 500"));
        assert_eq!(s.matches("\"iters\"").count(), 2);
    }

    #[test]
    fn throughput() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            stddev_ns: 0.0,
            min_ns: 1e9,
            max_ns: 1e9,
        };
        assert!((r.throughput(1000.0) - 1000.0).abs() < 1e-6);
    }
}
