//! Bench regression gate: compare the `BENCH_<name>.json` reports the
//! smoke benches leave at the repo root against committed baselines in
//! `rust/benches/baselines/`, and fail loudly on drift.
//!
//! Two failure classes, both CI-fatal (ROADMAP track 3b — perf
//! trajectories must be load-bearing, not scrollback):
//!
//! * **stale** — a gated report is missing, unparseable, empty, or was
//!   produced under a different profile than its baseline (smoke vs
//!   full numbers are never comparable);
//! * **regressed** — a benchmark disappeared/appeared relative to the
//!   baseline name set, or its median latency grew beyond the allowed
//!   ratio (default 1.5×; generous because CI machines are noisy, tight
//!   enough to catch an accidental O(n) → O(n²)).
//!
//! Baselines are committed by `dsq bench publish` after a deliberate
//! perf change. A fresh baseline may instead be the bootstrap marker
//! `{"bootstrap": true}`: the gate then checks the current report's
//! *structure* only (it exists, parses, and has positive medians) and
//! reminds the operator to publish — so the gate is live from the first
//! CI run even though committed numbers from a dev machine would be
//! meaningless.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};
use crate::{Error, Result};

/// Reports the gate covers: every name here must have a committed
/// baseline (or bootstrap marker) and a fresh `BENCH_<name>.json`.
pub const GATED: &[&str] = &["quantizer", "stash", "exchange"];

/// Default allowed median-latency growth before a bench counts as
/// regressed.
pub const DEFAULT_RATIO: f64 = 1.5;

/// One parsed bench report: the profile it ran under and each
/// benchmark's median latency.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchDoc {
    pub bench: String,
    pub profile: String,
    /// `(name, median_ns)` in file order.
    pub results: Vec<(String, f64)>,
    /// True for a committed `{"bootstrap": true}` placeholder baseline.
    pub bootstrap: bool,
}

impl BenchDoc {
    /// Parse a `BENCH_<name>.json` (or baseline) file.
    pub fn load(path: &Path) -> Result<BenchDoc> {
        let j = json::parse_file(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        Self::from_json(&j, path)
    }

    fn from_json(j: &Json, path: &Path) -> Result<BenchDoc> {
        let bootstrap = j.path("bootstrap").and_then(Json::as_bool).unwrap_or(false);
        let bench = j.path("bench").and_then(Json::as_str).unwrap_or_default().to_string();
        let profile = j.path("profile").and_then(Json::as_str).unwrap_or_default().to_string();
        let mut results = Vec::new();
        for r in j.path("results").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = r
                .path("name")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    Error::Config(format!("{}: result without a name", path.display()))
                })?
                .to_string();
            let median = r.path("median_ns").and_then(Json::as_f64).ok_or_else(|| {
                Error::Config(format!("{}: '{name}' has no median_ns", path.display()))
            })?;
            results.push((name, median));
        }
        if bench.is_empty() && !bootstrap {
            return Err(Error::Config(format!(
                "{}: not a bench report (no \"bench\" field)",
                path.display()
            )));
        }
        Ok(BenchDoc { bench, profile, results, bootstrap })
    }

    fn median_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|(n, _)| n == name).map(|&(_, m)| m)
    }
}

/// Compare one current report against its baseline. Returns findings
/// (empty = pass). Pure so the drift fixtures can feed it synthetic
/// documents.
pub fn compare(name: &str, baseline: &BenchDoc, current: &BenchDoc, ratio: f64) -> Vec<String> {
    let mut findings = Vec::new();
    if current.results.is_empty() {
        findings.push(format!("{name}: stale — current report has no results"));
        return findings;
    }
    if current.results.iter().any(|&(_, m)| !m.is_finite() || m <= 0.0) {
        findings.push(format!("{name}: stale — non-positive median in current report"));
    }
    if baseline.bootstrap {
        // Structural checks only; numbers start counting once published.
        return findings;
    }
    if baseline.profile != current.profile {
        findings.push(format!(
            "{name}: stale — profile '{}' vs baseline '{}' (not comparable)",
            current.profile, baseline.profile
        ));
        return findings;
    }
    for (bname, base) in &baseline.results {
        match current.median_of(bname) {
            None => findings.push(format!(
                "{name}: regressed — benchmark '{bname}' vanished from the report"
            )),
            Some(cur) if cur > base * ratio => findings.push(format!(
                "{name}: regressed — '{bname}' median {:.0} ns vs baseline {:.0} ns \
                 (> {ratio}x)",
                cur, base
            )),
            Some(_) => {}
        }
    }
    for (cname, _) in &current.results {
        if baseline.median_of(cname).is_none() {
            findings.push(format!(
                "{name}: stale — new benchmark '{cname}' not in the baseline \
                 (publish to accept it)"
            ));
        }
    }
    findings
}

/// Where a gated report lives: current at the repo root (where
/// [`super::JsonReport::write`] puts it), baseline committed under
/// `rust/benches/baselines/`.
pub fn report_paths(root: &Path, name: &str) -> (PathBuf, PathBuf) {
    (
        root.join(format!("BENCH_{name}.json")),
        root.join("rust/benches/baselines").join(format!("BENCH_{name}.json")),
    )
}

/// Run the gate over every [`GATED`] report. `Ok(notes)` when clean
/// (notes flag any bootstrap baselines still awaiting a publish);
/// `Err(Error::Lint)` listing every finding otherwise.
pub fn run_gate(root: &Path, ratio: f64) -> Result<Vec<String>> {
    let mut findings = Vec::new();
    let mut notes = Vec::new();
    for name in GATED {
        let (cur_path, base_path) = report_paths(root, name);
        let baseline = match BenchDoc::load(&base_path) {
            Ok(b) => b,
            Err(e) => {
                findings.push(format!("{name}: no usable baseline — {e}"));
                continue;
            }
        };
        let current = match BenchDoc::load(&cur_path) {
            Ok(c) => c,
            Err(e) => {
                findings.push(format!(
                    "{name}: stale — no current report ({e}); run the smoke bench first"
                ));
                continue;
            }
        };
        findings.extend(compare(name, &baseline, &current, ratio));
        if baseline.bootstrap && findings.is_empty() {
            notes.push(format!(
                "{name}: baseline is a bootstrap marker — `dsq bench publish` to pin numbers"
            ));
        }
    }
    if findings.is_empty() {
        Ok(notes)
    } else {
        Err(Error::Lint(findings.join("\n")))
    }
}

/// Copy every current gated report over its committed baseline (the
/// deliberate-perf-change workflow). Errors if any current report is
/// missing or malformed — a baseline must always parse.
pub fn publish(root: &Path) -> Result<Vec<PathBuf>> {
    let mut published = Vec::new();
    for name in GATED {
        let (cur_path, base_path) = report_paths(root, name);
        BenchDoc::load(&cur_path)?; // must parse before it can be a baseline
        if let Some(dir) = base_path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::copy(&cur_path, &base_path)?;
        published.push(base_path);
    }
    Ok(published)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(profile: &str, results: &[(&str, f64)]) -> BenchDoc {
        BenchDoc {
            bench: "x".into(),
            profile: profile.into(),
            results: results.iter().map(|&(n, m)| (n.to_string(), m)).collect(),
            bootstrap: false,
        }
    }

    #[test]
    fn clean_comparison_passes() {
        let base = doc("smoke", &[("a", 100.0), ("b", 200.0)]);
        let cur = doc("smoke", &[("a", 120.0), ("b", 150.0)]);
        assert!(compare("t", &base, &cur, 1.5).is_empty());
    }

    #[test]
    fn median_regression_fires() {
        let base = doc("smoke", &[("a", 100.0)]);
        let cur = doc("smoke", &[("a", 151.0)]);
        let f = compare("t", &base, &cur, 1.5);
        assert_eq!(f.len(), 1);
        assert!(f[0].contains("regressed") && f[0].contains("'a'"), "{f:?}");
    }

    #[test]
    fn name_set_drift_fires_both_ways() {
        let base = doc("smoke", &[("a", 100.0), ("gone", 50.0)]);
        let cur = doc("smoke", &[("a", 100.0), ("new", 50.0)]);
        let f = compare("t", &base, &cur, 1.5);
        assert!(f.iter().any(|m| m.contains("'gone' vanished")), "{f:?}");
        assert!(f.iter().any(|m| m.contains("'new'") && m.contains("not in the baseline")), "{f:?}");
    }

    #[test]
    fn profile_mismatch_and_empty_report_are_stale() {
        let base = doc("full", &[("a", 100.0)]);
        let cur = doc("smoke", &[("a", 100.0)]);
        let f = compare("t", &base, &cur, 1.5);
        assert!(f.iter().any(|m| m.contains("stale") && m.contains("profile")), "{f:?}");
        let f = compare("t", &base, &doc("full", &[]), 1.5);
        assert!(f.iter().any(|m| m.contains("no results")), "{f:?}");
    }

    #[test]
    fn bootstrap_baseline_checks_structure_only() {
        let base = BenchDoc {
            bench: String::new(),
            profile: String::new(),
            results: vec![],
            bootstrap: true,
        };
        let cur = doc("smoke", &[("a", 100.0)]);
        assert!(compare("t", &base, &cur, 1.5).is_empty());
        let f = compare("t", &base, &doc("smoke", &[("a", 0.0)]), 1.5);
        assert!(f.iter().any(|m| m.contains("non-positive")), "{f:?}");
        assert!(compare("t", &base, &doc("smoke", &[]), 1.5)[0].contains("no results"));
    }

    #[test]
    fn load_parses_real_reports_and_rejects_junk() {
        let dir = std::env::temp_dir().join(format!("dsq-gate-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("BENCH_good.json");
        std::fs::write(
            &good,
            "{\"bench\": \"stash\", \"profile\": \"smoke\", \"results\": [\
             {\"name\": \"enc\", \"median_ns\": 42.5}]}",
        )
        .unwrap();
        let d = BenchDoc::load(&good).unwrap();
        assert_eq!(d.bench, "stash");
        assert_eq!(d.results, vec![("enc".to_string(), 42.5)]);
        assert!(!d.bootstrap);
        let boot = dir.join("BENCH_boot.json");
        std::fs::write(&boot, "{\"bootstrap\": true}").unwrap();
        assert!(BenchDoc::load(&boot).unwrap().bootstrap);
        let junk = dir.join("BENCH_junk.json");
        std::fs::write(&junk, "{\"profile\": \"smoke\"}").unwrap();
        assert!(BenchDoc::load(&junk).is_err());
        std::fs::write(&junk, "not json").unwrap();
        assert!(BenchDoc::load(&junk).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gate_covers_the_committed_baselines() {
        // Every gated name must have a committed baseline file — the
        // gate's own contract with the repo layout.
        let cwd = std::env::current_dir().unwrap();
        let Some(root) = crate::analysis::find_root(&cwd) else { return };
        for name in GATED {
            let (_, base) = report_paths(&root, name);
            assert!(base.is_file(), "missing committed baseline {}", base.display());
            BenchDoc::load(&base).expect("committed baseline must parse");
        }
    }
}
