//! Spill-tier correctness suite for the tiered stash store
//! (`dsq::stash`), PJRT-free: property tests that spill → readback is
//! bit-identical to `encode(quantize(x))` across ragged shapes,
//! NaN/±inf payloads, and empty tensors — at both budget extremes
//! (0 = all-spill, unlimited = all-resident) — plus traffic-meter
//! agreement and checkpoint streaming through a spilled state.
//!
//! CI runs this file as its own job (`cargo test -q --test
//! stash_spill`) next to the stash-store smoke bench.

use dsq::model::ModelState;
use dsq::quant::{same_f32, Codec, FormatSpec, PackedTensor, FORMAT_REGISTRY};
use dsq::runtime::{HostTensor, TensorData};
use dsq::stash::{StashBudget, StashStore};
use dsq::util::prop::{gen_f32s, Prop};

fn state_of(tensors: Vec<HostTensor>, step: u64) -> ModelState {
    let zeros: Vec<HostTensor> = tensors.iter().map(HostTensor::zeros_like).collect();
    ModelState { params: tensors, m: zeros.clone(), v: zeros, step }
}

/// Stash `state` through a store at `budget`, force readback, and
/// return the packed params.
fn roundtrip(state: &mut ModelState, spec: FormatSpec, budget: StashBudget) -> Vec<PackedTensor> {
    let mut store = StashStore::ephemeral(spec, budget).unwrap();
    store.stash_state(state).unwrap();
    if budget == StashBudget::Bytes(0) {
        assert_eq!(
            StashStore::resident_bytes(state),
            0,
            "budget 0 must leave nothing resident"
        );
        assert!(
            store.traffic().spill_write_bytes > 0
                || state.params.iter().all(HostTensor::is_empty)
        );
    } else {
        assert!(!store.traffic().spilled(), "unlimited budget must never spill");
    }
    store.fetch_state(state).unwrap();
    state
        .params
        .iter()
        .map(|t| match &t.data {
            TensorData::Packed(p) => p.clone(),
            other => panic!("expected packed after fetch, got {other:?}"),
        })
        .collect()
}

#[test]
fn spill_readback_is_encode_of_quantize_property() {
    // The satellite property: across every registered family, random
    // (possibly ragged) shapes, and NaN/±inf payloads, the payload that
    // comes back from the spill tier is bit-identical to
    // encode(quantize(x)) — i.e. to what the resident tier holds.
    Prop::new("spill -> readback == encode(quantize(x))").cases(60).run(
        |rng, size| {
            let fam = &FORMAT_REGISTRY[rng.below(FORMAT_REGISTRY.len() as u32) as usize];
            let bits = rng.range(fam.min_bits, fam.max_bits + 1);
            let spec = fam.instantiate(bits).unwrap();
            let inner = 1 + rng.below(40) as usize;
            let rows = rng.below(4) as usize;
            let tail = rng.below(inner as u32) as usize; // ragged trailing row
            let mut x = gen_f32s(rng, rows * inner + tail, 4.0 + size as f32 / 8.0);
            for _ in 0..rng.below(4) {
                if x.is_empty() {
                    break;
                }
                let i = rng.below(x.len() as u32) as usize;
                x[i] = *rng.choice(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0]);
            }
            let step = rng.below(100) as u64;
            (spec, x, inner, step)
        },
        |(spec, x, inner, step)| {
            let shape = vec![x.len()];
            // What the resident tier would hold: the codec's packing of
            // the quantized tensor, at the state-stash (step, stream).
            let want = spec.encode_stream(x, &shape, *inner, *step, dsq::quant::stash_stream(0, 0));
            let t = HostTensor { shape, data: TensorData::F32(x.clone()) };
            // inner is the minor axis: reshape so the store packs against it.
            let t = if x.len() % *inner == 0 && !x.is_empty() {
                HostTensor::f32(vec![x.len() / *inner, *inner], x.clone())
            } else {
                t
            };
            let mut state = state_of(vec![t], *step);
            let got = roundtrip(&mut state, *spec, StashBudget::Bytes(0));
            let back = &got[0];
            // Compare decoded values under NaN-aware equality; the
            // payload bytes must match exactly when shapes align.
            let dec = back.decode();
            let mut qwant = x.clone();
            let use_inner =
                if x.len() % *inner == 0 && !x.is_empty() { *inner } else { x.len().max(1) };
            spec.quantize_into_stream(&mut qwant, use_inner, *step, dsq::quant::stash_stream(0, 0));
            if dec.len() != qwant.len() {
                return Err(format!("{spec}: length {} != {}", dec.len(), qwant.len()));
            }
            for (i, (&g, &w)) in dec.iter().zip(&qwant).enumerate() {
                if !same_f32(g, w) {
                    return Err(format!(
                        "{spec}: elem {i}: readback {g} != quantized {w} (x={})",
                        x[i]
                    ));
                }
            }
            // When the reshape kept the original minor axis, the raw
            // payload must also be byte-identical to encode().
            if x.len() % *inner == 0 && !x.is_empty() && back.payload() != want.payload() {
                return Err(format!("{spec}: payload bytes differ after spill readback"));
            }
            Ok(())
        },
    );
}

#[test]
fn budget_extremes_agree_bit_for_bit() {
    // The same state through budget-0 and unlimited stores must end up
    // identical — residency is not numerics.
    for spec in [
        FormatSpec::bfp(4),
        FormatSpec::fixed_sr(6),
        FormatSpec::fp8e4m3(),
        FormatSpec::Fp32,
    ] {
        let mk = || {
            state_of(
                vec![
                    HostTensor::f32(vec![4, 16], (0..64).map(|x| x as f32 * 0.31 - 9.0).collect()),
                    HostTensor::f32(vec![2, 21], (0..42).map(|x| (x as f32).cos() * 2.0).collect()),
                ],
                7,
            )
        };
        let (mut a, mut b) = (mk(), mk());
        let pa = roundtrip(&mut a, spec, StashBudget::Bytes(0));
        let pb = roundtrip(&mut b, spec, StashBudget::Unlimited);
        assert_eq!(pa, pb, "{spec}: spilled and resident tiers must hold the same bytes");
    }
}

#[test]
fn nan_inf_and_empty_tensors_survive_the_spill_tier() {
    for spec in [FormatSpec::bfp(4), FormatSpec::fixed(5), FormatSpec::fp8e5m2()] {
        let mut state = state_of(
            vec![
                HostTensor::f32(
                    vec![8],
                    vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0, 1.5, -3.25, 2e9],
                ),
                HostTensor::f32(vec![0, 4], vec![]),
                HostTensor::f32(vec![20], vec![f32::NAN; 20]),
            ],
            3,
        );
        let got = roundtrip(&mut state, spec, StashBudget::Bytes(0));
        let dec = got[0].decode();
        assert!(dec[0].is_nan(), "{spec}: NaN must survive spill");
        assert!(dec[1].is_finite() || dec[1].is_infinite());
        assert_eq!(got[1].len(), 0, "{spec}: empty tensor round-trips");
        assert!(got[2].decode().iter().all(|v| v.is_nan()), "{spec}: all-NaN tensor");
    }
}

#[test]
fn meter_agrees_with_the_model_at_both_budget_extremes() {
    for budget in [StashBudget::Bytes(0), StashBudget::Unlimited] {
        let mut state = state_of(
            vec![HostTensor::f32(vec![6, 32], (0..192).map(|x| x as f32 * 0.13).collect())],
            1,
        );
        let mut store = StashStore::ephemeral(FormatSpec::bfp(4), budget).unwrap();
        store.stash_state(&mut state).unwrap();
        store.fetch_state(&mut state).unwrap();
        store.note_dispatch_read(&state);
        let r = store.traffic_report();
        assert!(
            r.agrees(),
            "budget {budget}: observed {} vs modeled {} (allowance {})",
            r.meter.observed_stash_bits(),
            r.meter.modeled_stash_bits,
            r.allowance_bits
        );
        match budget {
            StashBudget::Bytes(0) => assert!(r.meter.spilled(), "budget 0 must spill"),
            _ => assert!(!r.meter.spilled(), "unlimited must not spill"),
        }
    }
}

#[test]
fn spilled_state_checkpoints_match_resident_checkpoints() {
    use dsq::model::checkpoint::{load_checkpoint, save_checkpoint};
    use dsq::runtime::{ModelManifest, ParamSpec};

    let mm = ModelManifest {
        config: Default::default(),
        params: vec![
            ParamSpec { name: "enc.w".into(), shape: vec![4, 16] },
            ParamSpec { name: "dec.w".into(), shape: vec![2, 21] },
        ],
        artifacts: Default::default(),
    };
    let mk = || {
        state_of(
            vec![
                HostTensor::f32(vec![4, 16], (0..64).map(|x| x as f32 * 0.5 - 16.0).collect()),
                HostTensor::f32(vec![2, 21], (0..42).map(|x| x as f32 * -0.25).collect()),
            ],
            11,
        )
    };
    let spec = FormatSpec::bfp(4);
    let tmp = |n: &str| {
        std::env::temp_dir().join(format!("dsq-spilltest-{}-{n}", std::process::id()))
    };

    // Resident reference.
    let mut resident = mk();
    resident.pack_state(&spec).unwrap();
    let p1 = tmp("resident.bin");
    save_checkpoint(&p1, &resident, &mm).unwrap();

    // Fully spilled state streams its records.
    let mut spilled = mk();
    let mut store = StashStore::ephemeral(spec, StashBudget::Bytes(0)).unwrap();
    store.stash_state(&mut spilled).unwrap();
    assert!(spilled.is_spilled());
    let p2 = tmp("spilled.bin");
    save_checkpoint(&p2, &spilled, &mm).unwrap();

    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "spilled checkpoint must be byte-identical to the resident one"
    );
    let back = load_checkpoint(&p2, &mm).unwrap();
    assert_eq!(back.params, resident.params);
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}
