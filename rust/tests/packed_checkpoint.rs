//! Integration: packed (v2) checkpoints round-trip trainer state
//! bit-identically, shrink on disk by the format's true storage ratio,
//! and the on-disk layout is pinned by golden bytes.
//!
//! Runs without artifacts: the checkpoint path is pure host-side code
//! (manifest + state are synthesized, as the unit tests do).

use dsq::model::{load_checkpoint, save_checkpoint, save_checkpoint_packed, ModelState};
use dsq::quant::{same_f32, Codec, FormatSpec};
use dsq::runtime::{HostTensor, ModelManifest, ParamSpec};
use dsq::util::prop::gen_f32s;
use dsq::util::rng::Pcg32;

fn manifest() -> ModelManifest {
    ModelManifest {
        config: Default::default(),
        params: vec![
            ParamSpec { name: "dec.proj.w".into(), shape: vec![64, 64] },
            ParamSpec { name: "enc.emb.w".into(), shape: vec![128, 32] },
            ParamSpec { name: "enc.ln.b".into(), shape: vec![96] },
        ],
        artifacts: Default::default(),
    }
}

/// A deterministic "trained" state: wide-magnitude params, nonzero
/// moments, nonzero step.
fn state(seed: u64) -> ModelState {
    let mm = manifest();
    let mut rng = Pcg32::new(seed);
    let mut tensors = |scale: f32| -> Vec<HostTensor> {
        mm.params
            .iter()
            .map(|s| {
                let x: Vec<f32> =
                    gen_f32s(&mut rng, s.numel(), 8.0).iter().map(|v| v * scale).collect();
                HostTensor::f32(s.shape.clone(), x)
            })
            .collect()
    };
    let params = tensors(1.0);
    let m = tensors(0.01);
    let v = tensors(0.0001);
    ModelState { params, m, v, step: 1234 }
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("dsq-packed-ckpt-{}-{name}", std::process::id()))
}

#[test]
fn packed_checkpoint_resumes_bit_identically() {
    let mm = manifest();
    for spec in [FormatSpec::bfp(4), FormatSpec::bfp(16), FormatSpec::fixed(8), FormatSpec::fixed_sr(6)]
    {
        let st = state(7);
        let path = tmpfile(&format!("resume-{spec}.bin"));
        save_checkpoint_packed(&path, &st, &mm, &spec).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        // Reload: state comes back packed, step intact, and the decoded
        // values are exactly the quantized grid values of the original.
        let resumed = load_checkpoint(&path, &mm).unwrap();
        assert_eq!(resumed.step, 1234);
        assert!(resumed.is_packed());
        let mut dense = resumed.clone();
        dense.unpack_state();
        for (orig, got) in st.params.iter().zip(&dense.params) {
            let inner = *orig.shape.last().unwrap();
            let want = spec
                .encode_stream(orig.as_f32().unwrap(), &orig.shape, inner, st.step, 0)
                .decode();
            // SR streams are per-tensor; compare against the packed
            // record itself for an exact statement below instead.
            if !spec.is_stochastic() {
                assert_eq!(got.as_f32().unwrap().len(), want.len());
                for (&g, &w) in got.as_f32().unwrap().iter().zip(&want) {
                    assert!(same_f32(g, w), "{spec}: decoded {g} != quantized {w}");
                }
            }
        }

        // Save the resumed state again: the file must be byte-identical
        // (no decode-reencode drift anywhere in the path).
        let path2 = tmpfile(&format!("resume2-{spec}.bin"));
        save_checkpoint(&path2, &resumed, &mm).unwrap();
        assert_eq!(bytes, std::fs::read(&path2).unwrap(), "{spec}: resave drifted");

        // And a third generation through save_checkpoint_packed (the
        // already-packed fast path) is also identical.
        let path3 = tmpfile(&format!("resume3-{spec}.bin"));
        save_checkpoint_packed(&path3, &resumed, &mm, &spec).unwrap();
        assert_eq!(bytes, std::fs::read(&path3).unwrap(), "{spec}: repack drifted");

        for p in [&path, &path2, &path3] {
            std::fs::remove_file(p).ok();
        }
    }
}

#[test]
fn bfp4_checkpoint_is_under_0p15x_of_fp32() {
    let mm = manifest();
    let st = state(11);
    let dense_path = tmpfile("size-fp32.bin");
    let packed_path = tmpfile("size-bfp4.bin");
    save_checkpoint(&dense_path, &st, &mm).unwrap();
    save_checkpoint_packed(&packed_path, &st, &mm, &FormatSpec::bfp(4)).unwrap();
    let dense = std::fs::metadata(&dense_path).unwrap().len() as f64;
    let packed = std::fs::metadata(&packed_path).unwrap().len() as f64;
    assert!(
        packed <= 0.15 * dense,
        "bfp4 checkpoint is {packed} B vs fp32 {dense} B ({:.3}x, want <= 0.15x)",
        packed / dense
    );
    std::fs::remove_file(&dense_path).ok();
    std::fs::remove_file(&packed_path).ok();
}

#[test]
fn dense_and_packed_checkpoints_coexist() {
    // A dense save stays v1 (readable by older code paths); packing the
    // same state produces v2; both load back through the same entry.
    let mm = manifest();
    let st = state(3);
    let v1 = tmpfile("coexist-v1.bin");
    let v2 = tmpfile("coexist-v2.bin");
    save_checkpoint(&v1, &st, &mm).unwrap();
    save_checkpoint_packed(&v2, &st, &mm, &FormatSpec::fixed(16)).unwrap();
    assert_eq!(&std::fs::read(&v1).unwrap()[..8], b"DSQCKPT1");
    assert_eq!(&std::fs::read(&v2).unwrap()[..8], b"DSQCKPT2");
    let a = load_checkpoint(&v1, &mm).unwrap();
    let b = load_checkpoint(&v2, &mm).unwrap();
    assert!(!a.is_packed());
    assert!(b.is_packed());
    assert_eq!(a.step, b.step);
    std::fs::remove_file(&v1).ok();
    std::fs::remove_file(&v2).ok();
}

#[test]
fn checkpoint_v2_golden_preamble() {
    // Pin the v2 framing: magic, step, group count, first group's tensor
    // count, then the first tensor record (name + versioned packed
    // header). A change here is an on-disk format break.
    let mm = manifest();
    let st = state(5);
    let path = tmpfile("golden-v2.bin");
    save_checkpoint_packed(&path, &st, &mm, &FormatSpec::bfp(4)).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let mut want: Vec<u8> = Vec::new();
    want.extend_from_slice(b"DSQCKPT2");
    want.extend_from_slice(&1234u64.to_le_bytes()); // adam step
    want.extend_from_slice(&3u32.to_le_bytes()); // group count
    want.extend_from_slice(&3u32.to_le_bytes()); // tensors in group 0
    want.extend_from_slice(&10u32.to_le_bytes()); // name length
    want.extend_from_slice(b"dec.proj.w");
    // Packed record header: version 1, bfp tag 3, 4 bits, flags 0,
    // inner 64, ndims 2, dims 64 x 64, payload length 64/16*9*64.
    want.extend_from_slice(&[1, 3, 4, 0]);
    want.extend_from_slice(&64u32.to_le_bytes());
    want.extend_from_slice(&2u32.to_le_bytes());
    want.extend_from_slice(&64u64.to_le_bytes());
    want.extend_from_slice(&64u64.to_le_bytes());
    want.extend_from_slice(&(4 * 9 * 64u64).to_le_bytes());
    assert_eq!(&bytes[..want.len()], &want[..], "v2 checkpoint preamble drifted");
    std::fs::remove_file(&path).ok();
}

#[test]
fn packed_state_numerics_survive_a_simulated_resume() {
    // The trainer-side contract without PJRT: absorb a fake step output
    // into a packed-state model, checkpoint, reload, and verify the
    // resident packed payloads are identical to pre-save.
    let mm = manifest();
    let spec = FormatSpec::bfp(8);
    let mut st = state(13);
    st.pack_state(&spec).unwrap();

    // Fake train-step output (dense, as PJRT returns it).
    let mut rng = Pcg32::new(99);
    let mut outs: Vec<HostTensor> = Vec::new();
    for scale in [1.0f32, 0.01, 0.0001] {
        for s in &mm.params {
            let x: Vec<f32> =
                gen_f32s(&mut rng, s.numel(), 6.0).iter().map(|v| v * scale).collect();
            outs.push(HostTensor::f32(s.shape.clone(), x));
        }
    }
    outs.push(HostTensor::scalar_f32(0.75));
    let loss = st.absorb_step_output(outs).unwrap();
    assert_eq!(loss, 0.75);
    st.pack_state(&spec).unwrap();
    assert!(st.is_packed());

    let path = tmpfile("simulated-resume.bin");
    save_checkpoint(&path, &st, &mm).unwrap();
    let resumed = load_checkpoint(&path, &mm).unwrap();
    assert_eq!(resumed.step, st.step);
    for (a, b) in st.params.iter().zip(&resumed.params) {
        assert_eq!(a, b, "packed param drifted across the checkpoint");
    }
    for (a, b) in st.v.iter().zip(&resumed.v) {
        assert_eq!(a, b, "packed moment drifted across the checkpoint");
    }
    std::fs::remove_file(&path).ok();
}
