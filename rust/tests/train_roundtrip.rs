//! Integration: full training steps through the rust PJRT runtime.
//!
//! Exercises the whole request path the coordinator uses: init -> train
//! steps (with runtime-dynamic precision) -> eval -> greedy decode, all
//! from rust, no python.

use std::path::{Path, PathBuf};

use dsq::runtime::{ArtifactManifest, HostTensor, Runtime};
use dsq::util::rng::Pcg32;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

struct NmtHarness {
    man: ArtifactManifest,
    params: Vec<HostTensor>,
    m: Vec<HostTensor>,
    v: Vec<HostTensor>,
    step: f32,
}

impl NmtHarness {
    fn new(dir: &Path, seed: i32) -> Self {
        let man = ArtifactManifest::load(dir).unwrap();
        let rt = Runtime::global();
        let init = rt.load(&man.model_path("nmt", "init").unwrap()).unwrap();
        let params = init.run(&[HostTensor::scalar_i32(seed)]).unwrap();
        let zeros: Vec<HostTensor> =
            man.nmt.params.iter().map(|s| HostTensor::zeros(&s.shape)).collect();
        NmtHarness { man, params, m: zeros.clone(), v: zeros, step: 0.0 }
    }

    fn batch(&self, rng: &mut Pcg32) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
        let b = self.man.nmt.cfg("batch").unwrap();
        let s = self.man.nmt.cfg("src_len").unwrap();
        let t = self.man.nmt.cfg("tgt_len").unwrap();
        let vocab = self.man.nmt.cfg("vocab").unwrap() as u32;
        // Copy task: tgt = src.
        let mut src = vec![0i32; b * s];
        for row in src.chunks_mut(s) {
            let len = rng.range(s as u32 / 2, s as u32) as usize;
            for tok in row.iter_mut().take(len) {
                *tok = rng.range(3, vocab) as i32;
            }
        }
        let mut tgt_in = vec![0i32; b * t];
        let mut tgt_out = vec![0i32; b * t];
        for i in 0..b {
            tgt_in[i * t] = 1; // BOS
            for j in 0..t - 1 {
                tgt_in[i * t + j + 1] = src[i * s + j];
            }
            let n = t.min(s);
            tgt_out[i * t..i * t + n].copy_from_slice(&src[i * s..i * s + n]);
        }
        (src, tgt_in, tgt_out)
    }

    fn train_step(&mut self, qcfg: [f32; 8], lr: f32, rng: &mut Pcg32) -> f32 {
        let rt = Runtime::global();
        let exe = rt.load(&self.man.model_path("nmt", "train_bfp").unwrap()).unwrap();
        let b = self.man.nmt.cfg("batch").unwrap();
        let s = self.man.nmt.cfg("src_len").unwrap();
        let t = self.man.nmt.cfg("tgt_len").unwrap();
        let (src, tgt_in, tgt_out) = self.batch(rng);
        self.step += 1.0;
        let mut inputs: Vec<HostTensor> = Vec::new();
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.push(HostTensor::scalar_f32(self.step));
        inputs.push(HostTensor::i32(vec![b, s], src));
        inputs.push(HostTensor::i32(vec![b, t], tgt_in));
        inputs.push(HostTensor::i32(vec![b, t], tgt_out));
        inputs.push(HostTensor::f32(vec![8], qcfg.to_vec()));
        inputs.push(HostTensor::scalar_f32(lr));
        let outs = exe.run(&inputs).unwrap();
        let n = self.man.nmt.params.len();
        assert_eq!(outs.len(), 3 * n + 1);
        self.params = outs[0..n].to_vec();
        self.m = outs[n..2 * n].to_vec();
        self.v = outs[2 * n..3 * n].to_vec();
        outs[3 * n].item_f32().unwrap()
    }
}

#[test]
fn train_loss_decreases_fp32_and_dsq() {
    let Some(dir) = artifacts_dir() else { return };
    for (name, qcfg) in [
        ("fp32", [0.0f32, 32.0, 0.0, 32.0, 0.0, 32.0, 0.0, 32.0]),
        ("dsq[2,2,2,16]", [2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 16.0]),
        ("stash-bfp[16,4,4,16]", [2.0, 16.0, 2.0, 4.0, 2.0, 4.0, 2.0, 16.0]),
    ] {
        let mut h = NmtHarness::new(&dir, 0);
        // One fixed batch pool of 2 batches: memorization = trainability.
        let mut first = None;
        let mut last = 0.0;
        for i in 0..30 {
            let mut brng = Pcg32::new(1000 + (i % 2) as u64);
            let loss = h.train_step(qcfg, 3e-3, &mut brng);
            assert!(loss.is_finite(), "{name}: non-finite loss at step {i}");
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
        }
        let first = first.unwrap();
        assert!(
            last < first - 0.02,
            "{name}: loss did not decrease ({first} -> {last})"
        );
        eprintln!("{name}: loss {first:.4} -> {last:.4} over 30 steps");
    }
}

#[test]
fn runtime_dynamic_precision_change_no_recompile() {
    // The DSQ controller's core requirement: changing qcfg between steps
    // works on the SAME executable.
    let Some(dir) = artifacts_dir() else { return };
    let mut h = NmtHarness::new(&dir, 7);
    let mut rng = Pcg32::new(9);
    let schedule = [
        [2.0f32, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 16.0],
        [2.0, 4.0, 2.0, 2.0, 2.0, 2.0, 2.0, 16.0],
        [2.0, 16.0, 2.0, 4.0, 2.0, 4.0, 2.0, 16.0],
        [2.0, 16.0, 2.0, 16.0, 2.0, 16.0, 2.0, 16.0],
        [0.0, 32.0, 0.0, 32.0, 0.0, 32.0, 0.0, 32.0],
    ];
    for q in schedule {
        let loss = h.train_step(q, 1e-3, &mut rng);
        assert!(loss.is_finite());
    }
}

#[test]
fn eval_and_decode_artifacts_run() {
    let Some(dir) = artifacts_dir() else { return };
    let h = NmtHarness::new(&dir, 3);
    let rt = Runtime::global();
    let mut rng = Pcg32::new(5);
    let (src, tgt_in, tgt_out) = h.batch(&mut rng);
    let b = h.man.nmt.cfg("batch").unwrap();
    let s = h.man.nmt.cfg("src_len").unwrap();
    let t = h.man.nmt.cfg("tgt_len").unwrap();

    let eval = rt.load(&h.man.model_path("nmt", "eval").unwrap()).unwrap();
    let mut inputs = h.params.clone();
    inputs.push(HostTensor::i32(vec![b, s], src.clone()));
    inputs.push(HostTensor::i32(vec![b, t], tgt_in));
    inputs.push(HostTensor::i32(vec![b, t], tgt_out.clone()));
    let outs = eval.run(&inputs).unwrap();
    assert_eq!(outs.len(), 3);
    let loss_sum = outs[0].item_f32().unwrap();
    let ncorrect = outs[1].item_f32().unwrap();
    let ntok = outs[2].item_f32().unwrap();
    let expected_ntok = tgt_out.iter().filter(|&&x| x != 0).count() as f32;
    assert_eq!(ntok, expected_ntok);
    assert!(loss_sum.is_finite() && loss_sum > 0.0);
    assert!((0.0..=ntok).contains(&ncorrect));

    let decode = rt.load(&h.man.model_path("nmt", "decode").unwrap()).unwrap();
    let mut inputs = h.params.clone();
    inputs.push(HostTensor::i32(vec![b, s], src));
    let outs = decode.run(&inputs).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape, vec![b, t]);
    let toks = outs[0].as_i32().unwrap();
    let vocab = h.man.nmt.cfg("vocab").unwrap() as i32;
    assert!(toks.iter().all(|&x| (0..vocab).contains(&x)));
    for i in 0..b {
        assert_eq!(toks[i * t], 1, "row {i} must start with BOS");
    }
}

#[test]
fn cls_train_and_eval_run() {
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    let rt = Runtime::global();
    let init = rt.load(&man.model_path("cls", "init").unwrap()).unwrap();
    let params = init.run(&[HostTensor::scalar_i32(0)]).unwrap();
    assert_eq!(params.len(), man.cls.params.len());

    let b = man.cls.cfg("batch").unwrap();
    let l = man.cls.cfg("seq_len").unwrap();
    let ncls = man.cls.cfg("nclasses").unwrap() as i32;
    let vocab = man.cls.cfg("vocab").unwrap() as u32;
    let mut rng = Pcg32::new(1);
    let mut toks = vec![0i32; b * l];
    let mut labels = vec![0i32; b];
    for i in 0..b {
        labels[i] = rng.below(ncls as u32) as i32;
        for j in 0..l {
            toks[i * l + j] = rng.range(4, vocab) as i32;
        }
        for j in 0..(2 * labels[i] as usize + 1) {
            toks[i * l + j] = 3;
        }
    }

    let zeros: Vec<HostTensor> =
        man.cls.params.iter().map(|s| HostTensor::zeros(&s.shape)).collect();
    let train = rt.load(&man.model_path("cls", "train_bfp").unwrap()).unwrap();
    let mut inputs: Vec<HostTensor> = params.clone();
    inputs.extend(zeros.clone());
    inputs.extend(zeros);
    inputs.push(HostTensor::scalar_f32(1.0));
    inputs.push(HostTensor::i32(vec![b, l], toks.clone()));
    inputs.push(HostTensor::i32(vec![b], labels.clone()));
    inputs.push(HostTensor::f32(vec![8], vec![2.0, 16.0, 2.0, 4.0, 2.0, 4.0, 2.0, 16.0]));
    inputs.push(HostTensor::scalar_f32(1e-3));
    let outs = train.run(&inputs).unwrap();
    let n = man.cls.params.len();
    assert_eq!(outs.len(), 3 * n + 1);
    let loss = outs[3 * n].item_f32().unwrap();
    assert!(loss.is_finite() && loss > 0.0);

    let eval = rt.load(&man.model_path("cls", "eval").unwrap()).unwrap();
    let mut inputs = params;
    inputs.push(HostTensor::i32(vec![b, l], toks));
    inputs.push(HostTensor::i32(vec![b], labels));
    let outs = eval.run(&inputs).unwrap();
    assert_eq!(outs.len(), 3);
    assert_eq!(outs[2].item_f32().unwrap(), b as f32);
}
