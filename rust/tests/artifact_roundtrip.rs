//! Integration: the AOT artifacts load, compile and execute through the
//! rust PJRT runtime, and their numerics match the rust mirrors.
//!
//! Requires `make artifacts` (skipped with a message otherwise so unit
//! CI without python still passes).

use std::path::PathBuf;

use dsq::quant;
use dsq::runtime::{ArtifactManifest, HostTensor, Runtime};
use dsq::util::rng::Pcg32;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn gen_values(rng: &mut Pcg32, n: usize, span: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * ((rng.f32() * 2.0 - 1.0) * span).exp2()).collect()
}

#[test]
fn quant_bfp_artifact_matches_rust_mirror() {
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    let rt = Runtime::global();
    let exe = rt.load(&man.quant_path("quant_bfp").unwrap()).unwrap();
    let (rows, cols) = (man.quant_shape[0], man.quant_shape[1]);
    let mut rng = Pcg32::new(2023);
    for &bits in &[2.0f32, 3.0, 4.0, 8.0, 12.0, 16.0, 24.0, 25.0] {
        let x = gen_values(&mut rng, rows * cols, 10.0);
        let outs = exe
            .run(&[
                HostTensor::f32(vec![rows, cols], x.clone()),
                HostTensor::scalar_f32(bits),
            ])
            .unwrap();
        assert_eq!(outs.len(), 1);
        let got = outs[0].as_f32().unwrap();
        let want = quant::bfp_quantize(&x, cols, bits);
        assert_eq!(got, want.as_slice(), "bits={bits}: artifact != rust mirror");
    }
}

#[test]
fn quant_fixed_artifact_matches_rust_mirror() {
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    let rt = Runtime::global();
    let exe = rt.load(&man.quant_path("quant_fixed").unwrap()).unwrap();
    let (rows, cols) = (man.quant_shape[0], man.quant_shape[1]);
    let mut rng = Pcg32::new(77);
    for &bits in &[4.0f32, 8.0, 16.0, 25.0] {
        let x = gen_values(&mut rng, rows * cols, 8.0);
        let outs = exe
            .run(&[
                HostTensor::f32(vec![rows, cols], x.clone()),
                HostTensor::scalar_f32(bits),
            ])
            .unwrap();
        let got = outs[0].as_f32().unwrap();
        let want = quant::fixed_quantize(&x, bits);
        assert_eq!(got, want.as_slice(), "bits={bits}");
    }
}

#[test]
fn quant_artifact_extreme_values() {
    // Exercise the exponent-clamp and subnormal-step paths end to end.
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    let exe = Runtime::global().load(&man.quant_path("quant_bfp").unwrap()).unwrap();
    let (rows, cols) = (man.quant_shape[0], man.quant_shape[1]);
    let mut x = vec![0.0f32; rows * cols];
    // Huge box, tiny box, zero box, mixed-sign box.
    x[0] = 3.0e38;
    x[1] = -1.0e38;
    x[16] = 1.0e-38;
    x[17] = 3.0e-39;
    x[48] = 1.0;
    x[49] = -1.0;
    for &bits in &[2.0f32, 4.0, 16.0] {
        let outs = exe
            .run(&[HostTensor::f32(vec![rows, cols], x.clone()), HostTensor::scalar_f32(bits)])
            .unwrap();
        let got = outs[0].as_f32().unwrap();
        let want = quant::bfp_quantize(&x, cols, bits);
        assert_eq!(got, want.as_slice(), "bits={bits}");
    }
}

#[test]
fn quant_float_artifact_matches_rust_mirror() {
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    let Ok(path) = man.quant_path("quant_float") else {
        eprintln!("skipping: artifacts predate the float formats (rerun `make artifacts`)");
        return;
    };
    let exe = Runtime::global().load(&path).unwrap();
    let (rows, cols) = (man.quant_shape[0], man.quant_shape[1]);
    let mut rng = Pcg32::new(404);
    // codes 100*E + M: e4m3, e5m2, fp16, bf16, and an odd one.
    for &(e, m) in &[(4u32, 3u32), (5, 2), (5, 10), (8, 7), (3, 4)] {
        let x = gen_values(&mut rng, rows * cols, 12.0);
        let code = (100 * e + m) as f32;
        let outs = exe
            .run(&[HostTensor::f32(vec![rows, cols], x.clone()), HostTensor::scalar_f32(code)])
            .unwrap();
        let got = outs[0].as_f32().unwrap();
        let want = quant::float_quantize(&x, e, m);
        assert_eq!(got, want.as_slice(), "e{e}m{m}: artifact != rust mirror");
    }
}

/// The artifact-side dispatch contract (the headline bugfix): a
/// single-quantizer variant applies its kernel ONLY on an exact mode
/// match and is the identity on every other family's mode — it must
/// never run a foreign slot through its own grid.
#[test]
fn select_probe_variants_dispatch_on_exact_mode_match() {
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    let Ok(path_fixed) = man.quant_path("quant_select_fixed") else {
        eprintln!("skipping: artifacts predate the select probes (rerun `make artifacts`)");
        return;
    };
    let (rows, cols) = (man.quant_shape[0], man.quant_shape[1]);
    let mut rng = Pcg32::new(7);
    let x = gen_values(&mut rng, rows * cols, 6.0);
    let run = |path: &std::path::Path, mode: f32, bits: f32| -> Vec<f32> {
        let exe = Runtime::global().load(path).unwrap();
        let outs = exe
            .run(&[
                HostTensor::f32(vec![rows, cols], x.clone()),
                HostTensor::scalar_f32(mode),
                HostTensor::scalar_f32(bits),
            ])
            .unwrap();
        outs[0].as_f32().unwrap().to_vec()
    };
    let fixed8 = quant::fixed_quantize(&x, 8.0);
    let bfp8 = quant::bfp_quantize(&x, cols, 8.0);
    let e4m3 = quant::float_quantize(&x, 4, 3);

    // "fixed" variant: modes 1/3 quantize, modes 2/4 are identity (the
    // old `mode >= 1` dispatch returned fixed8 for ALL of these).
    assert_eq!(run(&path_fixed, 1.0, 8.0), fixed8);
    assert_eq!(run(&path_fixed, 3.0, 8.0), fixed8);
    assert_eq!(run(&path_fixed, 2.0, 8.0), x, "bfp mode through the fixed variant");
    assert_eq!(run(&path_fixed, 4.0, 403.0), x, "float mode through the fixed variant");
    assert_eq!(run(&path_fixed, 0.0, 32.0), x);

    // "bfp" variant: only mode 2 quantizes.
    let path_bfp = man.quant_path("quant_select_bfp").unwrap();
    assert_eq!(run(&path_bfp, 2.0, 8.0), bfp8);
    assert_eq!(run(&path_bfp, 1.0, 8.0), x, "fixed mode through the bfp variant");
    assert_eq!(run(&path_bfp, 3.0, 8.0), x, "fixed-sr mode through the bfp variant");
    assert_eq!(run(&path_bfp, 4.0, 403.0), x);

    // "float" variant: modes 4/5 quantize.
    let path_float = man.quant_path("quant_select_float").unwrap();
    assert_eq!(run(&path_float, 4.0, 403.0), e4m3);
    assert_eq!(run(&path_float, 5.0, 403.0), e4m3, "artifact-side SR rounds to nearest");
    assert_eq!(run(&path_float, 2.0, 8.0), x);
    assert_eq!(run(&path_float, 1.0, 8.0), x);

    // "both" carries every family at its own mode.
    let path_both = man.quant_path("quant_select_both").unwrap();
    assert_eq!(run(&path_both, 1.0, 8.0), fixed8);
    assert_eq!(run(&path_both, 2.0, 8.0), bfp8);
    assert_eq!(run(&path_both, 3.0, 8.0), fixed8);
    assert_eq!(run(&path_both, 4.0, 403.0), e4m3);
    assert_eq!(run(&path_both, 0.0, 32.0), x);
}

#[test]
fn nmt_init_is_deterministic_and_matches_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    let exe = Runtime::global().load(&man.model_path("nmt", "init").unwrap()).unwrap();
    let p1 = exe.run(&[HostTensor::scalar_i32(0)]).unwrap();
    let p2 = exe.run(&[HostTensor::scalar_i32(0)]).unwrap();
    let p3 = exe.run(&[HostTensor::scalar_i32(1)]).unwrap();
    assert_eq!(p1.len(), man.nmt.params.len());
    for (i, spec) in man.nmt.params.iter().enumerate() {
        assert_eq!(p1[i].shape, spec.shape, "param {} shape mismatch", spec.name);
        assert_eq!(p1[i], p2[i], "init not deterministic for {}", spec.name);
        let x = p1[i].as_f32().unwrap();
        assert!(x.iter().all(|v| v.is_finite()), "non-finite init in {}", spec.name);
    }
    // A different seed must change at least the embeddings.
    let emb_idx = man.nmt.params.iter().position(|p| p.name == "src_emb").unwrap();
    assert_ne!(p1[emb_idx], p3[emb_idx]);
}
