//! Multi-process socket-transport e2e (PR 9): the `--transport socket`
//! path driven against real child processes of the built `dsq` binary.
//!
//! Every test here is gated on `CARGO_BIN_EXE_dsq` (set by cargo for
//! integration tests of a package with a `dsq` binary) and skips
//! silently when it is absent, mirroring `lint_drift::cli_lint_exit_codes`.
//!
//! What is pinned:
//!
//! * **Cross-transport bit-identity** — the `exchange-selftest`
//!   collective over TCP loopback and over a Unix-domain socket both
//!   return rank 0 state bit-identical to the in-memory
//!   [`run_replicas`] result *and* to the untouched single-replica
//!   state (fp32 mirrored all-reduce is bit-transparent on every
//!   transport).
//! * **Teardown under a dead peer** — a worker process that injects a
//!   fault mid-run must propagate the abort to every surviving peer
//!   within the transport timeout (not hang), and the orchestrator's
//!   error must carry the *originating* message relayed through the
//!   hub, exactly as the in-memory transport's teardown test demands.
//! * **Per-rank telemetry** — a two-replica `--trace` run writes a
//!   rank-tagged trace + manifest pair per process into one shared
//!   directory, the top-level spans account for each rank's wall clock
//!   to within 5%, and `dsq trace` renders both ranks.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use dsq::coordinator::worker::{
    flat_state, orchestrate, selftest_run, selftest_run_traced, selftest_state,
};
use dsq::obs::analyze;
use dsq::quant::FormatSpec;
use dsq::stash::run_replicas;
use dsq::util::json::{self, Json};

fn bin() -> Option<PathBuf> {
    match option_env!("CARGO_BIN_EXE_dsq") {
        Some(p) => Some(PathBuf::from(p)),
        None => {
            eprintln!("skipping: CARGO_BIN_EXE_dsq not set (run via cargo test)");
            None
        }
    }
}

fn selftest_argv(extra: &[&str]) -> Vec<String> {
    ["--elems", "24", "--rounds", "3", "--comms", "fp32"]
        .iter()
        .chain(extra)
        .map(|s| s.to_string())
        .collect()
}

/// Run the 2-process selftest collective over `addr` and return rank
/// 0's flattened post-reduce state.
fn socket_selftest(addr: &str) -> dsq::Result<Vec<f32>> {
    let exe = bin().expect("caller checked");
    orchestrate(&exe, "exchange-selftest", &selftest_argv(&[]), addr, 2, FormatSpec::Fp32, |ex| {
        selftest_run(ex, 24, 3, None)
    })
}

#[test]
fn socket_selftest_is_bit_identical_to_mem_and_single_replica() {
    if bin().is_none() {
        return;
    }
    // The reference: a mirrored fp32 all-reduce computes (x + x) / 2 ==
    // x exactly, so the untouched synthetic state IS the expected
    // output on any correct transport.
    let single = flat_state(&selftest_state(24)).unwrap();
    let mem = run_replicas(2, FormatSpec::Fp32, |_rank, ex| selftest_run(ex, 24, 3, None))
        .expect("mem-transport selftest");
    let socket = socket_selftest("127.0.0.1:0").expect("socket-transport selftest");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&mem), bits(&single), "mem transport must be fp32 bit-transparent");
    assert_eq!(
        bits(&socket),
        bits(&single),
        "socket transport must match the single-replica state bit-for-bit"
    );
}

#[cfg(unix)]
#[test]
fn socket_selftest_over_a_unix_domain_socket() {
    if bin().is_none() {
        return;
    }
    let mut path = std::env::temp_dir();
    path.push(format!("dsq-socket-e2e-{}.sock", std::process::id()));
    let addr = path.to_str().expect("temp path is UTF-8").to_string();
    let single = flat_state(&selftest_state(24)).unwrap();
    let socket = socket_selftest(&addr).expect("unix-socket selftest");
    std::fs::remove_file(&path).ok();
    assert_eq!(
        socket.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        single.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "unix-domain transport must match the single-replica state bit-for-bit"
    );
}

#[test]
fn worker_death_mid_exchange_tears_down_every_peer_within_timeout() {
    let Some(exe) = bin() else { return };
    // Rank 1 (a real child process) injects a fault before its second
    // round. Rank 0 is already parked in round 1's collect; the abort
    // must be relayed through the hub and surface here promptly — well
    // under the 60s read timeout — carrying the originating message.
    let start = Instant::now();
    let err = orchestrate(
        &exe,
        "exchange-selftest",
        &selftest_argv(&["--die-rank", "1", "--die-round", "1"]),
        "127.0.0.1:0",
        2,
        FormatSpec::Fp32,
        |ex| selftest_run(ex, 24, 3, None),
    )
    .expect_err("a dead worker must fail the whole run")
    .to_string();
    let elapsed = start.elapsed();
    assert!(
        err.contains("injected a selftest fault"),
        "rank 0's error must relay the originating worker fault: {err}"
    );
    assert!(
        elapsed < Duration::from_secs(30),
        "teardown must beat the read timeout, took {elapsed:?}: {err}"
    );
}

#[test]
fn two_replica_socket_trace_writes_per_rank_manifests() {
    let Some(exe) = bin() else { return };
    // CI points DSQ_TRACE_SMOKE_DIR at a workspace path so the files
    // survive as artifacts; locally we use (and clean) a temp dir.
    let (dir, keep) = match std::env::var("DSQ_TRACE_SMOKE_DIR") {
        Ok(d) => (PathBuf::from(d), true),
        Err(_) => {
            let mut d = std::env::temp_dir();
            d.push(format!("dsq-trace-e2e-{}", std::process::id()));
            std::fs::remove_dir_all(&d).ok();
            (d, false)
        }
    };
    let dir_str = dir.to_str().expect("trace dir is UTF-8").to_string();
    let argv: Vec<String> =
        ["--elems", "4096", "--rounds", "5", "--comms", "fp32", "--trace", &dir_str]
            .iter()
            .map(|s| s.to_string())
            .collect();
    orchestrate(&exe, "exchange-selftest", &argv, "127.0.0.1:0", 2, FormatSpec::Fp32, |ex| {
        selftest_run_traced(ex, 4096, 5, None, Some(&dir))
    })
    .expect("traced socket selftest");

    // Every rank — the in-parent rank 0 and the real child process —
    // wrote its own rank-tagged trace + manifest pair into the shared
    // directory.
    for rank in 0..2 {
        let man_path = dir.join(format!("run.rank{rank}.json"));
        let trace_path = dir.join(format!("trace.rank{rank}.jsonl"));
        assert!(man_path.is_file(), "missing {}", man_path.display());
        assert!(trace_path.is_file(), "missing {}", trace_path.display());
        let man = json::parse_file(&man_path).unwrap();
        assert_eq!(man.get("schema").and_then(Json::as_str), Some("DSQTRCE1"));
        assert_eq!(man.get("rank").and_then(Json::as_i64), Some(rank));
        assert_eq!(man.get("steps").and_then(Json::as_i64), Some(5));

        // The acceptance bar: top-level phase totals account for the
        // step wall-clock to within 5% — the spans cover the loop.
        let wall_ns = man.get("wall_s").and_then(Json::as_f64).unwrap() * 1e9;
        let covered: f64 = man
            .get("phases")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter(|p| p.get("parent") == Some(&Json::Null))
            .map(|p| p.get("total_ns").and_then(Json::as_f64).unwrap())
            .sum();
        assert!(
            covered >= 0.95 * wall_ns && covered <= 1.05 * wall_ns,
            "rank {rank}: top-level spans cover {covered:.0} ns of {wall_ns:.0} ns wall"
        );
    }

    // The analyzer renders both ranks from the same directory.
    let runs = analyze::load_runs(&dir).expect("load manifests");
    assert_eq!(runs.len(), 2);
    let report = analyze::render(&runs);
    assert!(report.contains("exchange"), "breakdown must name the exchange phase:\n{report}");
    assert!(report.contains("rank 1"), "both ranks must render:\n{report}");
    if !keep {
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn worker_subcommand_without_a_hub_fails_cleanly() {
    let Some(exe) = bin() else { return };
    // A worker pointed at an address nobody serves must exit nonzero
    // with a connect error, not hang past its connect deadline.
    let start = Instant::now();
    let out = std::process::Command::new(&exe)
        .args(["worker", "--rank", "1", "--connect", "127.0.0.1:1", "--replicas", "2"])
        .output()
        .expect("run dsq worker");
    assert!(!out.status.success(), "connecting to a dead address must fail");
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "the connect retry loop must respect its deadline"
    );
}
