//! Multi-process socket-transport e2e (PR 9): the `--transport socket`
//! path driven against real child processes of the built `dsq` binary.
//!
//! Every test here is gated on `CARGO_BIN_EXE_dsq` (set by cargo for
//! integration tests of a package with a `dsq` binary) and skips
//! silently when it is absent, mirroring `lint_drift::cli_lint_exit_codes`.
//!
//! What is pinned:
//!
//! * **Cross-transport bit-identity** — the `exchange-selftest`
//!   collective over TCP loopback and over a Unix-domain socket both
//!   return rank 0 state bit-identical to the in-memory
//!   [`run_replicas`] result *and* to the untouched single-replica
//!   state (fp32 mirrored all-reduce is bit-transparent on every
//!   transport).
//! * **Teardown under a dead peer** — a worker process that injects a
//!   fault mid-run must propagate the abort to every surviving peer
//!   within the transport timeout (not hang), and the orchestrator's
//!   error must carry the *originating* message relayed through the
//!   hub, exactly as the in-memory transport's teardown test demands.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use dsq::coordinator::worker::{flat_state, orchestrate, selftest_run, selftest_state};
use dsq::quant::FormatSpec;
use dsq::stash::run_replicas;

fn bin() -> Option<PathBuf> {
    match option_env!("CARGO_BIN_EXE_dsq") {
        Some(p) => Some(PathBuf::from(p)),
        None => {
            eprintln!("skipping: CARGO_BIN_EXE_dsq not set (run via cargo test)");
            None
        }
    }
}

fn selftest_argv(extra: &[&str]) -> Vec<String> {
    ["--elems", "24", "--rounds", "3", "--comms", "fp32"]
        .iter()
        .chain(extra)
        .map(|s| s.to_string())
        .collect()
}

/// Run the 2-process selftest collective over `addr` and return rank
/// 0's flattened post-reduce state.
fn socket_selftest(addr: &str) -> dsq::Result<Vec<f32>> {
    let exe = bin().expect("caller checked");
    orchestrate(&exe, "exchange-selftest", &selftest_argv(&[]), addr, 2, FormatSpec::Fp32, |ex| {
        selftest_run(ex, 24, 3, None)
    })
}

#[test]
fn socket_selftest_is_bit_identical_to_mem_and_single_replica() {
    if bin().is_none() {
        return;
    }
    // The reference: a mirrored fp32 all-reduce computes (x + x) / 2 ==
    // x exactly, so the untouched synthetic state IS the expected
    // output on any correct transport.
    let single = flat_state(&selftest_state(24)).unwrap();
    let mem = run_replicas(2, FormatSpec::Fp32, |_rank, ex| selftest_run(ex, 24, 3, None))
        .expect("mem-transport selftest");
    let socket = socket_selftest("127.0.0.1:0").expect("socket-transport selftest");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&mem), bits(&single), "mem transport must be fp32 bit-transparent");
    assert_eq!(
        bits(&socket),
        bits(&single),
        "socket transport must match the single-replica state bit-for-bit"
    );
}

#[cfg(unix)]
#[test]
fn socket_selftest_over_a_unix_domain_socket() {
    if bin().is_none() {
        return;
    }
    let mut path = std::env::temp_dir();
    path.push(format!("dsq-socket-e2e-{}.sock", std::process::id()));
    let addr = path.to_str().expect("temp path is UTF-8").to_string();
    let single = flat_state(&selftest_state(24)).unwrap();
    let socket = socket_selftest(&addr).expect("unix-socket selftest");
    std::fs::remove_file(&path).ok();
    assert_eq!(
        socket.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        single.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "unix-domain transport must match the single-replica state bit-for-bit"
    );
}

#[test]
fn worker_death_mid_exchange_tears_down_every_peer_within_timeout() {
    let Some(exe) = bin() else { return };
    // Rank 1 (a real child process) injects a fault before its second
    // round. Rank 0 is already parked in round 1's collect; the abort
    // must be relayed through the hub and surface here promptly — well
    // under the 60s read timeout — carrying the originating message.
    let start = Instant::now();
    let err = orchestrate(
        &exe,
        "exchange-selftest",
        &selftest_argv(&["--die-rank", "1", "--die-round", "1"]),
        "127.0.0.1:0",
        2,
        FormatSpec::Fp32,
        |ex| selftest_run(ex, 24, 3, None),
    )
    .expect_err("a dead worker must fail the whole run")
    .to_string();
    let elapsed = start.elapsed();
    assert!(
        err.contains("injected a selftest fault"),
        "rank 0's error must relay the originating worker fault: {err}"
    );
    assert!(
        elapsed < Duration::from_secs(30),
        "teardown must beat the read timeout, took {elapsed:?}: {err}"
    );
}

#[test]
fn worker_subcommand_without_a_hub_fails_cleanly() {
    let Some(exe) = bin() else { return };
    // A worker pointed at an address nobody serves must exit nonzero
    // with a connect error, not hang past its connect deadline.
    let start = Instant::now();
    let out = std::process::Command::new(&exe)
        .args(["worker", "--rank", "1", "--connect", "127.0.0.1:1", "--replicas", "2"])
        .output()
        .expect("run dsq worker");
    assert!(!out.status.success(), "connecting to a dead address must fail");
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "the connect retry loop must respect its deadline"
    );
}
