//! Data-parallel milestone tests (PR 7): N in-process `Session`
//! replicas over the sharded batch stream, exchanging state every step
//! through the packed-record all-reduce in `dsq::stash::exchange`.
//!
//! Acceptance: a two-replica mirrored run under `--comms fp32` is
//! bit-identical to the single-replica run; quantized comms stay
//! within tolerance; and the comms meter's modeled `container_bits()`
//! agree with the codec-observed wire bytes within the box-metadata
//! allowance. Gated on `make artifacts` like `coordinator_e2e`.

use std::path::{Path, PathBuf};

use dsq::coordinator::{LrSchedule, Trainer, TrainerConfig};
use dsq::data::Variant;
use dsq::schedule::{FormatSpec, PrecisionConfig, Schedule, StaticSchedule};
use dsq::util::json::{self, Json};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn base_cfg(dir: &Path) -> TrainerConfig {
    TrainerConfig {
        epochs: 1,
        batches_per_epoch: 6,
        val_batches: 2,
        bleu_batches: 0,
        lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 20 },
        variant: Variant::Iwslt,
        ..TrainerConfig::quick(dir.to_path_buf())
    }
}

fn fp32_schedule() -> dsq::Result<Box<dyn Schedule>> {
    Ok(Box::new(StaticSchedule(PrecisionConfig::FP32)))
}

/// The comms meter acceptance shared by every replicated run: traffic
/// flowed in both directions and the modeled-vs-observed comparison
/// holds within the accumulated allowance.
fn assert_comms_metered(r: &dsq::coordinator::RunReport, spec: FormatSpec) {
    let c = r.comms.as_ref().expect("replicated run carries comms traffic");
    assert_eq!(c.replicas, 2);
    assert_eq!(c.spec, spec);
    assert!(c.meter.comms_tx_bytes > 0, "no bytes sent");
    assert!(c.meter.comms_rx_bytes > 0, "no bytes received");
    assert!(
        c.agrees(),
        "modeled {} vs observed {} bits (gap {}, allowance {})",
        c.meter.modeled_comms_bits,
        c.meter.observed_comms_bits(),
        c.gap_bits(),
        c.allowance_bits
    );
}

#[test]
fn two_mirrored_replicas_at_fp32_match_single_replica_bit_for_bit() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut single = Trainer::new(cfg.clone()).unwrap();
    let r1 = single.run(schedule.as_mut()).unwrap();
    assert_eq!(r1.steps, 6);
    assert!(r1.comms.is_none(), "single-replica runs meter no comms");

    let cfg2 = TrainerConfig {
        replicas: 2,
        mirror_replicas: true,
        comms: FormatSpec::Fp32,
        ..cfg
    };
    let r2 = Trainer::run_replicated(cfg2, fp32_schedule).unwrap();
    assert!(!r2.diverged);
    assert_eq!(r2.steps, r1.steps);
    // fp32 packed records carry raw bits and (x + x) / 2 == x exactly,
    // so the mirrored exchange is bit-transparent: every step loss and
    // every validation agree with the single-replica run to the last
    // bit.
    assert_eq!(r2.loss_curve, r1.loss_curve, "mirrored fp32 run must be bit-identical");
    assert_eq!(r2.val_curve, r1.val_curve);
    assert_eq!(r2.final_val_loss.to_bits(), r1.final_val_loss.to_bits());
    assert_comms_metered(&r2, FormatSpec::Fp32);
}

/// Run `dsq train` through the real binary with `extra` flags appended
/// to a fixed tiny fp32 config, and return the parsed `--json` report.
/// The socket-transport run and its references all go through this one
/// argv, so the only degree of freedom is the replication quad.
fn train_via_binary(bin: &str, dir: &Path, extra: &[&str]) -> Json {
    let mut args = vec![
        "train".to_string(),
        "--artifacts".to_string(),
        dir.to_string_lossy().into_owned(),
        "--epochs".to_string(),
        "1".to_string(),
        "--batches-per-epoch".to_string(),
        "6".to_string(),
        "--val-batches".to_string(),
        "2".to_string(),
        "--bleu-batches".to_string(),
        "0".to_string(),
        "--lr".to_string(),
        "isqrt:3e-3:20".to_string(),
        "--schedule".to_string(),
        "fp32".to_string(),
        "--json".to_string(),
    ];
    args.extend(extra.iter().map(|s| s.to_string()));
    let out = std::process::Command::new(bin).args(&args).output().expect("run dsq train");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(
        out.status.success(),
        "dsq train {extra:?} failed; stdout:\n{stdout}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    // The JSON report is the last thing printed: parse from the final
    // line holding a lone `{` (worker processes share the stream, so
    // summary lines may precede it).
    let mut at = None;
    let mut pos = 0usize;
    for l in stdout.lines() {
        if l.trim() == "{" {
            at = Some(pos);
        }
        pos += l.len() + 1;
    }
    let at = at.unwrap_or_else(|| panic!("no JSON report in stdout:\n{stdout}"));
    json::parse(&stdout[at..]).expect("report parses as JSON")
}

fn loss_curve_of(report: &Json) -> Vec<(f64, f64)> {
    report
        .get("loss_curve")
        .and_then(Json::as_arr)
        .expect("report has a loss_curve")
        .iter()
        .map(|pair| {
            let p = pair.as_arr().expect("curve entry is [step, loss]");
            (p[0].as_f64().unwrap(), p[1].as_f64().unwrap())
        })
        .collect()
}

#[test]
fn socket_transport_train_matches_mem_and_single_replica_bit_for_bit() {
    // The PR 9 acceptance e2e: the same `dsq train` argv through the
    // same binary, three ways — single replica, two mirrored in-memory
    // replicas, and two mirrored replicas as real OS processes over
    // `--transport socket` — must agree on every step loss and the
    // final validation loss exactly. Needs both the built binary and
    // `make artifacts`.
    let Some(bin) = option_env!("CARGO_BIN_EXE_dsq") else { return };
    let Some(dir) = artifacts_dir() else { return };
    let single = train_via_binary(bin, &dir, &[]);
    let mem = train_via_binary(
        bin,
        &dir,
        &["--replicas", "2", "--mirror-replicas", "--comms", "fp32"],
    );
    let socket = train_via_binary(
        bin,
        &dir,
        &[
            "--replicas",
            "2",
            "--mirror-replicas",
            "--comms",
            "fp32",
            "--transport",
            "socket:127.0.0.1:0",
        ],
    );
    let reference = loss_curve_of(&single);
    assert!(!reference.is_empty());
    assert_eq!(loss_curve_of(&mem), reference, "mem transport drifted from single-replica");
    assert_eq!(
        loss_curve_of(&socket),
        reference,
        "socket transport drifted from single-replica"
    );
    let final_loss = |r: &Json| r.get("final_val_loss").and_then(Json::as_f64).unwrap();
    assert_eq!(final_loss(&mem), final_loss(&single));
    assert_eq!(final_loss(&socket), final_loss(&single));
}

#[test]
fn run_replicated_with_one_replica_is_the_plain_path() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let r1 = t.run(schedule.as_mut()).unwrap();
    // `--replicas 1` short-circuits to exactly Trainer::new + run —
    // today's path bit-for-bit, with no exchange and no comms column.
    let r2 = Trainer::run_replicated(cfg, fp32_schedule).unwrap();
    assert_eq!(r2.loss_curve, r1.loss_curve);
    assert_eq!(r2.final_val_loss.to_bits(), r1.final_val_loss.to_bits());
    assert!(r2.comms.is_none());
}

#[test]
fn mirrored_replicas_with_quantized_comms_stay_within_tolerance() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut single = Trainer::new(cfg.clone()).unwrap();
    let r1 = single.run(schedule.as_mut()).unwrap();

    // Same mirrored stream, but the exchange dequant-reduce-requants
    // through fixed8 SR records: the trajectory picks up bounded
    // rounding noise and must stay near the fp32 one, not match it.
    let cfg2 = TrainerConfig {
        replicas: 2,
        mirror_replicas: true,
        comms: FormatSpec::fixed_sr(8),
        ..cfg
    };
    let r2 = Trainer::run_replicated(cfg2, fp32_schedule).unwrap();
    assert!(!r2.diverged);
    assert_eq!(r2.steps, r1.steps);
    assert_comms_metered(&r2, FormatSpec::fixed_sr(8));
    let rel = (r2.final_val_loss - r1.final_val_loss).abs() / r1.final_val_loss.abs().max(1e-9);
    assert!(
        rel < 0.25,
        "q8 comms drifted: final val loss {} vs fp32 {} (rel {rel:.3})",
        r2.final_val_loss,
        r1.final_val_loss
    );
    let (first_q, first_f) = (r2.loss_curve[0].1, r1.loss_curve[0].1);
    let rel0 = (first_q - first_f).abs() / first_f.abs().max(1e-9);
    assert!(rel0 < 0.25, "first-step loss off: {first_q} vs {first_f} (rel {rel0:.3})");
}

#[test]
fn round_robin_replicas_with_quantized_comms_track_the_single_replica_run() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut single = Trainer::new(cfg.clone()).unwrap();
    let r1 = single.run(schedule.as_mut()).unwrap();

    // Round-robin (the default): two replicas deal a 12-batch global
    // stream and take 6 owned steps each — the 2×-batch emulation the
    // milestone asks for. The per-step loss is the rank-averaged loss
    // over two distinct batches, so the trajectory tracks the
    // single-replica one within batch-noise tolerance rather than
    // matching it bitwise.
    let cfg2 = TrainerConfig {
        replicas: 2,
        mirror_replicas: false,
        comms: FormatSpec::fixed_sr(8),
        ..cfg
    };
    let r2 = Trainer::run_replicated(cfg2, fp32_schedule).unwrap();
    assert!(!r2.diverged);
    assert_eq!(r2.steps, r1.steps, "each rank owns batches_per_epoch steps");
    assert_comms_metered(&r2, FormatSpec::fixed_sr(8));
    assert!(r2.final_val_loss.is_finite());
    let rel = (r2.final_val_loss - r1.final_val_loss).abs() / r1.final_val_loss.abs().max(1e-9);
    assert!(
        rel < 0.25,
        "2x-batch emulation diverged from single-replica trajectory: \
         final val loss {} vs {} (rel {rel:.3})",
        r2.final_val_loss,
        r1.final_val_loss
    );
}
