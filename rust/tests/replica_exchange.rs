//! Data-parallel milestone tests (PR 7): N in-process `Session`
//! replicas over the sharded batch stream, exchanging state every step
//! through the packed-record all-reduce in `dsq::stash::exchange`.
//!
//! Acceptance: a two-replica mirrored run under `--comms fp32` is
//! bit-identical to the single-replica run; quantized comms stay
//! within tolerance; and the comms meter's modeled `container_bits()`
//! agree with the codec-observed wire bytes within the box-metadata
//! allowance. Gated on `make artifacts` like `coordinator_e2e`.

use std::path::{Path, PathBuf};

use dsq::coordinator::{LrSchedule, Trainer, TrainerConfig};
use dsq::data::Variant;
use dsq::schedule::{FormatSpec, PrecisionConfig, Schedule, StaticSchedule};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn base_cfg(dir: &Path) -> TrainerConfig {
    TrainerConfig {
        epochs: 1,
        batches_per_epoch: 6,
        val_batches: 2,
        bleu_batches: 0,
        lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 20 },
        variant: Variant::Iwslt,
        ..TrainerConfig::quick(dir.to_path_buf())
    }
}

fn fp32_schedule() -> dsq::Result<Box<dyn Schedule>> {
    Ok(Box::new(StaticSchedule(PrecisionConfig::FP32)))
}

/// The comms meter acceptance shared by every replicated run: traffic
/// flowed in both directions and the modeled-vs-observed comparison
/// holds within the accumulated allowance.
fn assert_comms_metered(r: &dsq::coordinator::RunReport, spec: FormatSpec) {
    let c = r.comms.as_ref().expect("replicated run carries comms traffic");
    assert_eq!(c.replicas, 2);
    assert_eq!(c.spec, spec);
    assert!(c.meter.comms_tx_bytes > 0, "no bytes sent");
    assert!(c.meter.comms_rx_bytes > 0, "no bytes received");
    assert!(
        c.agrees(),
        "modeled {} vs observed {} bits (gap {}, allowance {})",
        c.meter.modeled_comms_bits,
        c.meter.observed_comms_bits(),
        c.gap_bits(),
        c.allowance_bits
    );
}

#[test]
fn two_mirrored_replicas_at_fp32_match_single_replica_bit_for_bit() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut single = Trainer::new(cfg.clone()).unwrap();
    let r1 = single.run(schedule.as_mut()).unwrap();
    assert_eq!(r1.steps, 6);
    assert!(r1.comms.is_none(), "single-replica runs meter no comms");

    let cfg2 = TrainerConfig {
        replicas: 2,
        mirror_replicas: true,
        comms: FormatSpec::Fp32,
        ..cfg
    };
    let r2 = Trainer::run_replicated(cfg2, fp32_schedule).unwrap();
    assert!(!r2.diverged);
    assert_eq!(r2.steps, r1.steps);
    // fp32 packed records carry raw bits and (x + x) / 2 == x exactly,
    // so the mirrored exchange is bit-transparent: every step loss and
    // every validation agree with the single-replica run to the last
    // bit.
    assert_eq!(r2.loss_curve, r1.loss_curve, "mirrored fp32 run must be bit-identical");
    assert_eq!(r2.val_curve, r1.val_curve);
    assert_eq!(r2.final_val_loss.to_bits(), r1.final_val_loss.to_bits());
    assert_comms_metered(&r2, FormatSpec::Fp32);
}

#[test]
fn run_replicated_with_one_replica_is_the_plain_path() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let r1 = t.run(schedule.as_mut()).unwrap();
    // `--replicas 1` short-circuits to exactly Trainer::new + run —
    // today's path bit-for-bit, with no exchange and no comms column.
    let r2 = Trainer::run_replicated(cfg, fp32_schedule).unwrap();
    assert_eq!(r2.loss_curve, r1.loss_curve);
    assert_eq!(r2.final_val_loss.to_bits(), r1.final_val_loss.to_bits());
    assert!(r2.comms.is_none());
}

#[test]
fn mirrored_replicas_with_quantized_comms_stay_within_tolerance() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut single = Trainer::new(cfg.clone()).unwrap();
    let r1 = single.run(schedule.as_mut()).unwrap();

    // Same mirrored stream, but the exchange dequant-reduce-requants
    // through fixed8 SR records: the trajectory picks up bounded
    // rounding noise and must stay near the fp32 one, not match it.
    let cfg2 = TrainerConfig {
        replicas: 2,
        mirror_replicas: true,
        comms: FormatSpec::fixed_sr(8),
        ..cfg
    };
    let r2 = Trainer::run_replicated(cfg2, fp32_schedule).unwrap();
    assert!(!r2.diverged);
    assert_eq!(r2.steps, r1.steps);
    assert_comms_metered(&r2, FormatSpec::fixed_sr(8));
    let rel = (r2.final_val_loss - r1.final_val_loss).abs() / r1.final_val_loss.abs().max(1e-9);
    assert!(
        rel < 0.25,
        "q8 comms drifted: final val loss {} vs fp32 {} (rel {rel:.3})",
        r2.final_val_loss,
        r1.final_val_loss
    );
    let (first_q, first_f) = (r2.loss_curve[0].1, r1.loss_curve[0].1);
    let rel0 = (first_q - first_f).abs() / first_f.abs().max(1e-9);
    assert!(rel0 < 0.25, "first-step loss off: {first_q} vs {first_f} (rel {rel0:.3})");
}

#[test]
fn round_robin_replicas_with_quantized_comms_track_the_single_replica_run() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = base_cfg(&dir);
    let mut schedule = fp32_schedule().unwrap();
    let mut single = Trainer::new(cfg.clone()).unwrap();
    let r1 = single.run(schedule.as_mut()).unwrap();

    // Round-robin (the default): two replicas deal a 12-batch global
    // stream and take 6 owned steps each — the 2×-batch emulation the
    // milestone asks for. The per-step loss is the rank-averaged loss
    // over two distinct batches, so the trajectory tracks the
    // single-replica one within batch-noise tolerance rather than
    // matching it bitwise.
    let cfg2 = TrainerConfig {
        replicas: 2,
        mirror_replicas: false,
        comms: FormatSpec::fixed_sr(8),
        ..cfg
    };
    let r2 = Trainer::run_replicated(cfg2, fp32_schedule).unwrap();
    assert!(!r2.diverged);
    assert_eq!(r2.steps, r1.steps, "each rank owns batches_per_epoch steps");
    assert_comms_metered(&r2, FormatSpec::fixed_sr(8));
    assert!(r2.final_val_loss.is_finite());
    let rel = (r2.final_val_loss - r1.final_val_loss).abs() / r1.final_val_loss.abs().max(1e-9);
    assert!(
        rel < 0.25,
        "2x-batch emulation diverged from single-replica trajectory: \
         final val loss {} vs {} (rel {rel:.3})",
        r2.final_val_loss,
        r1.final_val_loss
    );
}
