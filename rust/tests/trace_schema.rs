//! Golden tests pinning the telemetry schema (`DSQTRCE1`): the trace
//! JSONL event shape, the `run.rank<N>.json` manifest shape, and the
//! span-attributed-bytes vs `TrafficMeter` consistency contract on a
//! real two-replica exchange.
//!
//! Anything that changes these assertions is a schema break and must
//! bump `dsq::obs::TRACE_MAGIC`.

use std::path::PathBuf;

use dsq::coordinator::worker::{flat_state, selftest_run_traced, selftest_state};
use dsq::obs::{schema_str, Phase, Recorder, RunInfo, TRACE_MAGIC};
use dsq::quant::FormatSpec;
use dsq::stash::run_replicas;
use dsq::util::json::{self, Json};

fn tmpdir(tag: &str) -> PathBuf {
    let mut d = std::env::temp_dir();
    d.push(format!("dsq-trace-schema-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

#[test]
fn trace_magic_is_pinned() {
    // The versioned schema tag. Breaking the manifest or event shape
    // means bumping this constant (DSQTRCE2, ...) — and this test.
    assert_eq!(TRACE_MAGIC, b"DSQTRCE1");
    assert_eq!(schema_str().as_bytes(), b"DSQTRCE1");
}

#[test]
fn trace_jsonl_events_keep_their_golden_shape() {
    let dir = tmpdir("jsonl");
    let r = Recorder::to_dir(&dir, 3).unwrap();
    let s = r.span_start(Phase::StashWrite);
    r.span_close(s, 42, 1024);
    r.span_import(Phase::Quantize, 42, 500, 768);
    r.flush_events().unwrap();

    let trace = std::fs::read_to_string(dir.join("trace.rank3.jsonl")).unwrap();
    let lines: Vec<Json> = trace.lines().map(|l| json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 3, "header + 2 events: {trace}");

    // Header line: schema + kind + rank, nothing load-bearing beyond.
    assert_eq!(lines[0].get("schema").and_then(Json::as_str), Some("DSQTRCE1"));
    assert_eq!(lines[0].get("kind").and_then(Json::as_str), Some("header"));
    assert_eq!(lines[0].get("rank").and_then(Json::as_i64), Some(3));

    // Event lines: exactly the five pinned keys.
    for (ev, phase, bytes) in [(&lines[1], "stash_write", 1024), (&lines[2], "quantize", 768)] {
        let obj = ev.as_obj().unwrap();
        let mut keys: Vec<&str> = obj.keys().map(String::as_str).collect();
        keys.sort_unstable();
        assert_eq!(keys, ["bytes", "dur_ns", "phase", "step", "t_ns"]);
        assert_eq!(ev.get("phase").and_then(Json::as_str), Some(phase));
        assert_eq!(ev.get("step").and_then(Json::as_i64), Some(42));
        assert_eq!(ev.get("bytes").and_then(Json::as_i64), Some(bytes));
        assert!(ev.get("t_ns").and_then(Json::as_i64).unwrap() >= 0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_manifest_keeps_its_golden_shape() {
    let dir = tmpdir("manifest");
    let r = Recorder::to_dir(&dir, 0).unwrap();
    for step in 1..=4u64 {
        let s = r.span_start(Phase::Dispatch);
        r.span_close(s, step, 0);
        r.span_import(Phase::Quantize, step, 250, 64);
    }
    let info = RunInfo {
        argv: vec!["dsq".into(), "train".into()],
        config: Json::obj(vec![("seed", Json::num(7.0))]),
        steps: 4,
        wall_s: 0.25,
        stash: None,
        comms: None,
        ladder: vec![(1, "fp8_e4m3".into()), (3, "bfp:8:16".into())],
    };
    let path = r.finish_run(&info).unwrap().unwrap();
    assert!(path.ends_with("run.rank0.json"));
    let man = json::parse_file(&path).unwrap();

    // Top-level keys, pinned exactly.
    let mut keys: Vec<&str> = man.as_obj().unwrap().keys().map(String::as_str).collect();
    keys.sort_unstable();
    assert_eq!(
        keys,
        [
            "argv", "comms", "config", "events_dropped", "ladder", "phases", "rank", "schema",
            "stash", "steps", "wall_s"
        ]
    );
    assert_eq!(man.get("schema").and_then(Json::as_str), Some("DSQTRCE1"));
    assert_eq!(man.get("rank").and_then(Json::as_i64), Some(0));
    assert_eq!(man.get("steps").and_then(Json::as_i64), Some(4));
    assert_eq!(man.path("argv/1").and_then(Json::as_str), Some("train"));
    assert_eq!(man.path("config/seed").and_then(Json::as_i64), Some(7));
    assert_eq!(man.get("events_dropped").and_then(Json::as_i64), Some(0));
    assert_eq!(man.get("stash"), Some(&Json::Null));

    // Ladder rungs are (step, spec) objects in entry order.
    assert_eq!(man.path("ladder/0/step").and_then(Json::as_i64), Some(1));
    assert_eq!(man.path("ladder/1/spec").and_then(Json::as_str), Some("bfp:8:16"));

    // Phase entries: only phases with samples, top-level order first,
    // each carrying the full aggregate column set.
    let phases = man.get("phases").and_then(Json::as_arr).unwrap();
    assert_eq!(phases.len(), 2);
    let dispatch = &phases[0];
    assert_eq!(dispatch.get("phase").and_then(Json::as_str), Some("dispatch"));
    assert_eq!(dispatch.get("parent"), Some(&Json::Null));
    let mut pkeys: Vec<&str> =
        dispatch.as_obj().unwrap().keys().map(String::as_str).collect();
    pkeys.sort_unstable();
    assert_eq!(
        pkeys,
        ["bytes", "count", "max_ns", "min_ns", "p50_ns", "p95_ns", "parent", "phase", "total_ns"]
    );
    let quantize = &phases[1];
    assert_eq!(quantize.get("phase").and_then(Json::as_str), Some("quantize"));
    assert_eq!(quantize.get("parent").and_then(Json::as_str), Some("stash_write"));
    assert_eq!(quantize.get("count").and_then(Json::as_i64), Some(4));
    assert_eq!(quantize.get("total_ns").and_then(Json::as_i64), Some(1000));
    assert_eq!(quantize.get("bytes").and_then(Json::as_i64), Some(256));
    std::fs::remove_dir_all(&dir).ok();
}

/// The consistency contract: summed over ranks, the bytes the exchange
/// spans attribute to the `exchange` phase must equal the aggregate
/// `TrafficMeter` comms tx+rx columns — the span recorder and the meter
/// count the same wire, so they must agree exactly.
#[test]
fn exchange_span_bytes_match_the_traffic_meter() {
    let dir = tmpdir("consistency");
    let dir2 = dir.clone();
    let got = run_replicas(2, FormatSpec::Fp32, move |_rank, ex| {
        selftest_run_traced(ex, 96, 4, None, Some(&dir2))
    })
    .unwrap();

    let mut span_bytes = 0i64;
    let mut meter_bytes = None;
    for rank in 0..2 {
        let man = json::parse_file(&dir.join(format!("run.rank{rank}.json"))).unwrap();
        assert_eq!(man.get("schema").and_then(Json::as_str), Some("DSQTRCE1"));
        let phases = man.get("phases").and_then(Json::as_arr).unwrap();
        let exch = phases
            .iter()
            .find(|p| p.get("phase").and_then(Json::as_str) == Some("exchange"))
            .unwrap_or_else(|| panic!("rank {rank} manifest has no exchange phase"));
        assert_eq!(exch.get("count").and_then(Json::as_i64), Some(4));
        span_bytes += exch.get("bytes").and_then(Json::as_i64).unwrap();
        // Both ranks report the same aggregate meter (shared core).
        let tx = man.path("comms/comms_tx_bytes").and_then(Json::as_i64).unwrap();
        let rx = man.path("comms/comms_rx_bytes").and_then(Json::as_i64).unwrap();
        assert!(tx > 0 && rx > 0, "rank {rank}: tx {tx} rx {rx}");
        let total = tx + rx;
        assert_eq!(*meter_bytes.get_or_insert(total), total, "ranks disagree on the meter");
    }
    assert_eq!(
        span_bytes,
        meter_bytes.unwrap(),
        "span-attributed exchange bytes must equal the TrafficMeter comms columns"
    );

    // The nested sub-phases partition the same wire bytes: encode
    // attributes tx, reduce attributes rx.
    let man = json::parse_file(&dir.join("run.rank0.json")).unwrap();
    let phases = man.get("phases").and_then(Json::as_arr).unwrap();
    let bytes_of = |name: &str| {
        phases
            .iter()
            .find(|p| p.get("phase").and_then(Json::as_str) == Some(name))
            .and_then(|p| p.get("bytes"))
            .and_then(Json::as_i64)
            .unwrap_or_else(|| panic!("rank 0 manifest has no {name} phase"))
    };
    let exch0 = bytes_of("exchange");
    assert_eq!(bytes_of("exch_encode") + bytes_of("exch_reduce"), exch0);
    std::fs::remove_dir_all(&dir).ok();

    // And the state itself came back intact: tracing must not perturb
    // the mirrored fp32 bit-transparency contract.
    let want = flat_state(&selftest_state(96)).unwrap();
    assert_eq!(got, want, "tracing perturbed the mirrored fp32 selftest");
}
