//! End-to-end coordinator tests: real (tiny-budget) training runs through
//! the full L3 stack — synthetic corpus -> prefetch -> PJRT steps ->
//! validation -> controller -> BLEU -> checkpoint — all driven by the
//! task-agnostic Session engine.
//!
//! Budget note: PJRT compiles the train artifact once per process
//! (~100 s); the runs themselves are small.

use std::path::{Path, PathBuf};

use dsq::coordinator::{Finetuner, FinetuneConfig, LrSchedule, Trainer, TrainerConfig};
use dsq::data::Variant;
use dsq::model::checkpoint;
use dsq::runtime::ArtifactManifest;
use dsq::schedule::{DsqController, FormatSpec, PrecisionConfig, Schedule, StaticSchedule};
use dsq::stash::StashBudget;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn quick_cfg(dir: &Path) -> TrainerConfig {
    TrainerConfig {
        epochs: 2,
        batches_per_epoch: 8,
        val_batches: 2,
        bleu_batches: 2,
        lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 20 },
        variant: Variant::Iwslt,
        ..TrainerConfig::quick(dir.to_path_buf())
    }
}

#[test]
fn trainer_runs_and_improves_under_stashing_bfp() {
    let Some(dir) = artifacts_dir() else { return };
    let mut schedule: Box<dyn Schedule> =
        Box::new(StaticSchedule(PrecisionConfig::stashing(FormatSpec::bfp(16))));
    let mut trainer = Trainer::new(quick_cfg(&dir)).unwrap();
    let report = trainer.run(schedule.as_mut()).unwrap();
    assert_eq!(report.steps, 16);
    assert!(!report.diverged);
    assert!(report.final_val_loss.is_finite());
    assert!(report.bleu().is_some());
    assert!(report.accuracy().is_none(), "translation reports BLEU, not accuracy");
    // Training loss decreased within the tiny budget.
    let first = report.loss_curve.first().unwrap().1;
    let last = report.loss_curve.last().unwrap().1;
    assert!(last < first, "loss did not move: {first} -> {last}");
    // Trace accounted every step at the static config.
    assert_eq!(report.trace.len(), 1);
    assert_eq!(report.trace[0].1, 16);
    assert_eq!(report.trace[0].0.notation(), "[16,4,4,16]");
    // Memoized dispatch: one static config resolves exactly three
    // distinct executables for the whole run (train kind, eval, decode)
    // — not one load per step.
    assert_eq!(trainer.session().executables_loaded(), 3);
}

#[test]
fn dsq_controller_trace_feeds_cost_model() {
    let Some(dir) = artifacts_dir() else { return };
    let mut schedule: Box<dyn Schedule> =
        Box::new(DsqController::paper_default("bfp").unwrap());
    let mut trainer = Trainer::new(quick_cfg(&dir)).unwrap();
    let report = trainer.run(schedule.as_mut()).unwrap();
    let total: usize = report.trace.iter().map(|(_, n)| n).sum();
    assert_eq!(total as u64, report.steps);
    // Starting level must be the most aggressive.
    assert_eq!(report.trace[0].0.notation(), "[2,2,2,16]");
    // The cost trace evaluates on the paper workload.
    let w = dsq::costmodel::TransformerWorkload::iwslt_6layer();
    let (arith, dram) = report.cost_on(&w).expect("dsq trace is scored");
    assert!(arith > 0.0 && arith < 0.12, "arith {arith}");
    assert!(dram > 0.0 && dram < 0.6, "dram {dram}");
}

#[test]
fn fp8_schedule_trains_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let man = ArtifactManifest::load(&dir).unwrap();
    if man.nmt.artifact_file("train_float").is_err() {
        eprintln!("skipping: artifacts predate the float formats (rerun `make artifacts`)");
        return;
    }
    // The dsq-fp8 ladder: E4M3 fwd/stash/bwd with an E5M2 grad slot,
    // driven through the float train variant by the dispatch guard.
    let mut schedule: Box<dyn Schedule> = Box::new(DsqController::fp8_default().unwrap());
    let mut trainer = Trainer::new(quick_cfg(&dir)).unwrap();
    let report = trainer.run(schedule.as_mut()).unwrap();
    assert_eq!(report.steps, 16);
    assert!(!report.diverged, "fp8 run diverged");
    assert!(report.final_val_loss.is_finite());
    assert_eq!(report.trace[0].0.notation(), "[8,8,8,8]");
    assert_eq!(report.trace[0].0.grad(), FormatSpec::fp8e5m2());
    let total: usize = report.trace.iter().map(|(_, n)| n).sum();
    assert_eq!(total as u64, report.steps);
    // The float trace is scored by the cost model (FP8 MACs ~0.05x).
    let w = dsq::costmodel::TransformerWorkload::iwslt_6layer();
    let (arith, dram) = report.cost_on(&w).expect("fp8 trace is scored");
    assert!(arith > 0.0 && arith < 0.25, "arith {arith}");
    assert!(dram > 0.0 && dram < 0.75, "dram {dram}");
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(dir) = artifacts_dir() else { return };
    let ckpt = std::env::temp_dir().join(format!("dsq-e2e-ckpt-{}.bin", std::process::id()));
    let mut cfg = quick_cfg(&dir);
    cfg.epochs = 1;
    cfg.batches_per_epoch = 4;
    cfg.bleu_batches = 0;
    cfg.checkpoint = Some(ckpt.clone());
    let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(PrecisionConfig::FP32));
    let mut trainer = Trainer::new(cfg.clone()).unwrap();
    let r1 = trainer.run(schedule.as_mut()).unwrap();

    // Resume: state (including Adam step) must round-trip. A static
    // schedule has no resumable state, so the trailer is absent.
    let man = ArtifactManifest::load(&dir).unwrap();
    let (loaded, sched) = checkpoint::load_checkpoint_full(&ckpt, &man.nmt).unwrap();
    assert_eq!(loaded.step, r1.steps);
    assert_eq!(loaded.params.len(), man.nmt.params.len());
    assert_eq!(sched, None);

    let mut cfg2 = cfg.clone();
    cfg2.checkpoint = None;
    cfg2.init_checkpoint = Some(ckpt.clone());
    let mut trainer2 = Trainer::new(cfg2).unwrap();
    let r2 = trainer2.run(schedule.as_mut()).unwrap();
    assert_eq!(r2.steps, r1.steps + 4);
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn budgeted_stash_spill_matches_unbudgeted_run_exactly() {
    let Some(dir) = artifacts_dir() else { return };
    // Acceptance criterion: a --stash-budget smaller than the resident
    // working set completes with a bit-identical loss trajectory to the
    // unbudgeted run, reports spill traffic > 0, and the unbudgeted
    // case's modeled-vs-observed DRAM comparison agrees within
    // box-metadata slack.
    let mut cfg = quick_cfg(&dir);
    cfg.epochs = 1;
    cfg.batches_per_epoch = 4;
    cfg.bleu_batches = 0;
    cfg.stash_format = Some(FormatSpec::bfp(8));
    let mut schedule: Box<dyn Schedule> =
        Box::new(StaticSchedule(PrecisionConfig::stashing(FormatSpec::bfp(16))));

    let mut unbudgeted = Trainer::new(cfg.clone()).unwrap();
    let r1 = unbudgeted.run(schedule.as_mut()).unwrap();
    let t1 = r1.stash.as_ref().expect("stashed run carries traffic");
    assert!(!t1.meter.spilled(), "unlimited budget must not spill");
    assert!(t1.meter.stash_write_bytes > 0 && t1.meter.stash_read_bytes > 0);
    assert!(
        t1.agrees(),
        "modeled {} vs observed {} bits (allowance {})",
        t1.meter.modeled_stash_bits,
        t1.meter.observed_stash_bits(),
        t1.allowance_bits
    );

    // Budget 0: every slot spills to disk every step.
    let stash_dir = std::env::temp_dir().join(format!("dsq-e2e-stash-{}", std::process::id()));
    let mut cfg2 = cfg.clone();
    cfg2.stash_budget = StashBudget::Bytes(0);
    cfg2.stash_dir = Some(stash_dir.clone());
    let mut budgeted = Trainer::new(cfg2).unwrap();
    let r2 = budgeted.run(schedule.as_mut()).unwrap();
    let t2 = r2.stash.as_ref().unwrap();
    assert!(t2.meter.spill_write_bytes > 0, "a sub-working-set budget must spill");
    assert!(t2.meter.spill_read_bytes > 0, "spilled slots must read back");

    // Residency is not numerics: trajectories match exactly, step by step.
    assert_eq!(r1.loss_curve, r2.loss_curve, "budget changed the loss trajectory");
    assert_eq!(r1.final_val_loss, r2.final_val_loss);
    assert_eq!(r1.final_eval_acc, r2.final_eval_acc);

    // The on-disk index is inspectable (`dsq stash <dir>`).
    assert!(stash_dir.join("stash.json").exists());
    assert!(stash_dir.join("stash.seg").exists());
    std::fs::remove_dir_all(&stash_dir).ok();
}

#[test]
fn traced_run_manifest_matches_the_stash_traffic_meter() {
    let Some(dir) = artifacts_dir() else { return };
    // Telemetry consistency on a real stashed run: the bytes the
    // stash_read / stash_write spans attribute per step must line up
    // exactly with the TrafficMeter columns the report carries — the
    // only meter traffic outside the spans is the initial stash in
    // Session::new, one full-state write before step 1.
    let trace = std::env::temp_dir().join(format!("dsq-e2e-trace-{}", std::process::id()));
    std::fs::remove_dir_all(&trace).ok();
    let mut cfg = quick_cfg(&dir);
    cfg.epochs = 1;
    cfg.batches_per_epoch = 4;
    cfg.bleu_batches = 0;
    cfg.stash_format = Some(FormatSpec::bfp(8));
    cfg.trace_dir = Some(trace.clone());
    let mut schedule: Box<dyn Schedule> =
        Box::new(StaticSchedule(PrecisionConfig::stashing(FormatSpec::bfp(16))));
    let report = Trainer::new(cfg).unwrap().run(schedule.as_mut()).unwrap();
    let meter = report.stash.as_ref().expect("stashed run carries traffic").meter;

    let man = dsq::util::json::parse_file(&trace.join("run.rank0.json")).unwrap();
    use dsq::util::json::Json;
    assert_eq!(man.get("schema").and_then(Json::as_str), Some("DSQTRCE1"));
    assert_eq!(man.get("steps").and_then(Json::as_i64), Some(4));
    // The manifest's stash column IS the report's traffic, verbatim.
    assert_eq!(man.get("stash"), Some(&report.stash.as_ref().unwrap().to_json()));

    let phases = man.get("phases").and_then(Json::as_arr).unwrap();
    let agg = |name: &str| {
        phases
            .iter()
            .find(|p| p.get("phase").and_then(Json::as_str) == Some(name))
            .unwrap_or_else(|| panic!("manifest lacks the {name} phase"))
    };
    let bytes = |name: &str| agg(name).get("bytes").and_then(Json::as_i64).unwrap() as u64;

    // Reads are metered only at dispatch, always inside the span.
    assert_eq!(agg("stash_read").get("count").and_then(Json::as_i64), Some(4));
    assert_eq!(bytes("stash_read"), meter.stash_read_bytes + meter.spill_read_bytes);

    // Writes: 4 in-span step writes + the identical initial stash the
    // constructor does before the recorder sees anything — so the span
    // bytes are exactly 4/5 of the meter column.
    assert_eq!(agg("stash_write").get("count").and_then(Json::as_i64), Some(4));
    assert_eq!(bytes("stash_write") * 5, (meter.stash_write_bytes + meter.spill_write_bytes) * 4);

    // Unbudgeted: nothing spills, so the quantize sub-phase accounts
    // for every span-attributed write byte.
    assert_eq!(meter.spill_write_bytes, 0);
    assert_eq!(bytes("quantize"), bytes("stash_write"));

    // Every top-level phase the loop exercises is present with samples.
    for name in ["batch_wait", "dispatch", "stash_read", "stash_write", "validate"] {
        assert!(agg(name).get("count").and_then(Json::as_i64).unwrap() > 0, "{name} unsampled");
    }
    std::fs::remove_dir_all(&trace).ok();
}

#[test]
fn budgeted_stash_finetune_matches_unbudgeted_run_exactly() {
    let Some(dir) = artifacts_dir() else { return };
    // Same acceptance criterion on the classification task.
    let mk = |budget| FinetuneConfig {
        epochs: 1,
        batches_per_epoch: 4,
        val_batches: 2,
        nclasses: 3,
        lr: LrSchedule::Polynomial { lr: 1e-3, warmup_steps: 4, total_steps: 500 },
        stash_format: Some(FormatSpec::fixed(8)),
        stash_budget: budget,
        ..FinetuneConfig::quick(dir.clone())
    };
    let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(PrecisionConfig::FP32));
    let r1 = Finetuner::new(mk(StashBudget::Unlimited)).unwrap().run(schedule.as_mut()).unwrap();
    let r2 = Finetuner::new(mk(StashBudget::Bytes(0))).unwrap().run(schedule.as_mut()).unwrap();
    let (t1, t2) = (r1.stash.as_ref().unwrap(), r2.stash.as_ref().unwrap());
    assert!(!t1.meter.spilled() && t2.meter.spilled());
    assert!(t1.agrees(), "unbudgeted finetune modeled-vs-observed must agree");
    assert_eq!(r1.loss_curve, r2.loss_curve);
    assert_eq!(r1.accuracy(), r2.accuracy());
}

#[test]
fn finetuner_runs_and_reports_accuracy() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = FinetuneConfig {
        epochs: 2,
        batches_per_epoch: 8,
        val_batches: 2,
        nclasses: 3,
        lr: LrSchedule::Polynomial { lr: 1e-3, warmup_steps: 4, total_steps: 500 },
        ..FinetuneConfig::quick(dir.clone())
    };
    let mut schedule: Box<dyn Schedule> =
        Box::new(StaticSchedule(PrecisionConfig::stashing(FormatSpec::bfp(16))));
    let mut tuner = Finetuner::new(cfg).unwrap();
    let report = tuner.run(schedule.as_mut()).unwrap();
    assert_eq!(report.steps, 16);
    assert!(!report.diverged);
    let acc = report.accuracy().expect("classification reports accuracy");
    assert!((0.0..=1.0).contains(&acc));
    assert!(report.bleu().is_none(), "classification reports accuracy, not BLEU");
    assert!(report.final_val_loss.is_finite());
    // One train kind + eval; no decode artifact for the classifier.
    assert_eq!(tuner.session().executables_loaded(), 2);
}

#[test]
fn finetune_rejects_too_many_classes() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = FinetuneConfig { nclasses: 7, ..FinetuneConfig::quick(dir) };
    assert!(Finetuner::new(cfg).is_err());
}
