//! Schedule-state resume: a checkpoint written mid-DSQ-ladder must
//! restore the controller at the saved level/stale count, not silently
//! restart at `[2,2,2,16]`.
//!
//! The trailer round-trip and controller restore are covered without
//! PJRT (fake manifest); the full Session resume runs when `make
//! artifacts` has been done (same gating as `coordinator_e2e`).

use std::path::PathBuf;

use dsq::coordinator::{
    ExeCache, LrSchedule, NmtTask, Session, SessionConfig, Task, TaskMetric, Trainer,
    TrainerConfig,
};
use dsq::data::{Batch, Batcher, TranslationConfig, TranslationTask, Variant};
use dsq::model::checkpoint::ResumePosition;
use dsq::model::{checkpoint, ModelState};
use dsq::runtime::{ArtifactManifest, HostTensor, ModelManifest, ParamSpec};
use dsq::schedule::{
    DsqController, DsqControllerConfig, PrecisionConfig, Schedule, ScheduleState, StaticSchedule,
};
use dsq::stash::StashBudget;

fn fake_mm() -> ModelManifest {
    ModelManifest {
        config: Default::default(),
        params: vec![
            ParamSpec { name: "a.w".into(), shape: vec![2, 3] },
            ParamSpec { name: "b.b".into(), shape: vec![4] },
        ],
        artifacts: Default::default(),
    }
}

fn fake_state() -> ModelState {
    let p = vec![
        HostTensor::f32(vec![2, 3], (0..6).map(|x| x as f32).collect()),
        HostTensor::f32(vec![4], vec![-1.0, 0.5, 2.0, 3.5]),
    ];
    let m = vec![HostTensor::zeros(&[2, 3]), HostTensor::zeros(&[4])];
    ModelState { params: p, m: m.clone(), v: m, step: 7 }
}

fn tmpfile(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsq-resume-{}-{name}", std::process::id()))
}

/// Push a fresh paper-default controller to `level` with flat losses.
fn advance_to_level(ctl: &mut DsqController, level: usize) {
    ctl.observe_validation(5.0); // establishes best_loss
    while ctl.level() < level {
        ctl.observe_validation(5.0);
    }
    assert_eq!(ctl.level(), level);
}

#[test]
fn controller_snapshot_rides_checkpoint_trailer() {
    let mut ctl = DsqController::paper_default("bfp").unwrap();
    advance_to_level(&mut ctl, 2);
    let snap = ctl.snapshot().unwrap();
    assert_eq!(snap.level, 2);

    let path = tmpfile("trailer.bin");
    checkpoint::save_checkpoint_full(&path, &fake_state(), &fake_mm(), Some(&snap)).unwrap();
    let (state, restored) = checkpoint::load_checkpoint_full(&path, &fake_mm()).unwrap();
    assert_eq!(state.step, 7);
    let restored = restored.expect("trailer present");
    assert_eq!(restored, snap);

    // A fresh controller restored from the trailer continues the ladder
    // at level 2 — not at [2,2,2,16].
    let mut resumed = DsqController::paper_default("bfp").unwrap();
    assert_eq!(resumed.current().notation(), "[2,2,2,16]");
    resumed.restore(&restored);
    assert_eq!(resumed.level(), 2);
    assert_eq!(resumed.current(), ctl.current());
    assert_eq!(resumed.describe(), ctl.describe());
    std::fs::remove_file(&path).ok();
}

#[test]
fn stale_count_survives_resume() {
    // Half-way toward a bump (stale 1 of patience 2): after resume, ONE
    // more flat validation must advance the level — the plateau counter
    // carried over.
    let mut ctl = DsqController::paper_default("bfp").unwrap();
    ctl.observe_validation(5.0);
    ctl.observe_validation(5.0); // stale 1
    assert_eq!(ctl.level(), 0);
    let snap = ctl.snapshot().unwrap();
    assert_eq!(snap.stale, 1);

    let path = tmpfile("stale.bin");
    checkpoint::save_checkpoint_full(&path, &fake_state(), &fake_mm(), Some(&snap)).unwrap();
    let (_, restored) = checkpoint::load_checkpoint_full(&path, &fake_mm()).unwrap();
    let mut resumed = DsqController::paper_default("bfp").unwrap();
    resumed.restore(&restored.unwrap());
    resumed.observe_validation(5.0); // stale 2 -> bump
    assert_eq!(resumed.level(), 1);
    std::fs::remove_file(&path).ok();
}

#[test]
fn pre_trailer_checkpoints_resume_with_fresh_schedule() {
    let path = tmpfile("legacy.bin");
    checkpoint::save_checkpoint(&path, &fake_state(), &fake_mm()).unwrap();
    let (_, restored) = checkpoint::load_checkpoint_full(&path, &fake_mm()).unwrap();
    assert_eq!(restored, None, "no trailer = fresh schedule");
    std::fs::remove_file(&path).ok();
}

#[test]
fn restore_is_safe_across_ladder_lengths() {
    // A snapshot from a longer ladder clamps to the shorter one's top.
    let snap = ScheduleState { level: 5, stale: 0, observed: 12, best_loss: 2.0 };
    let cfg =
        DsqControllerConfig::from_specs(0.002, 2, &["bfp:2,2,2,16", "bfp:16,4,4,16"]).unwrap();
    let mut short = DsqController::new(cfg);
    short.restore(&snap);
    assert_eq!(short.level(), 1);
    assert!(short.at_top());
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn session_resumes_mid_ladder_e2e() {
    let Some(dir) = artifacts_dir() else { return };
    let ckpt = std::env::temp_dir().join(format!("dsq-resume-e2e-{}.bin", std::process::id()));
    let cfg = TrainerConfig {
        epochs: 2,
        batches_per_epoch: 4,
        val_batches: 2,
        bleu_batches: 0,
        lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 20 },
        variant: Variant::Iwslt,
        checkpoint: Some(ckpt.clone()),
        ..TrainerConfig::quick(dir.clone())
    };

    // Run 1 under a controller already mid-ladder (level 2).
    let mut ctl1 = DsqController::paper_default("bfp").unwrap();
    advance_to_level(&mut ctl1, 2);
    let mut trainer1 = Trainer::new(cfg.clone()).unwrap();
    let r1 = trainer1.run(&mut ctl1).unwrap();
    let saved_level = ctl1.level(); // >= 2, monotone
    assert!(saved_level >= 2);
    assert_eq!(r1.trace[0].0, ctl1_ladder_config(2));

    // Run 2: a FRESH controller plus --init-checkpoint must resume at
    // the saved level — its very first step runs at that config, not at
    // the ladder bottom.
    let cfg2 = TrainerConfig {
        checkpoint: None,
        init_checkpoint: Some(ckpt.clone()),
        ..cfg
    };
    let mut ctl2 = DsqController::paper_default("bfp").unwrap();
    assert_eq!(ctl2.level(), 0);
    let mut trainer2 = Trainer::new(cfg2).unwrap();
    let r2 = trainer2.run(&mut ctl2).unwrap();
    assert_eq!(r2.steps, r1.steps + 8);
    assert!(ctl2.level() >= saved_level, "ladder went backwards across resume");
    assert_eq!(
        r2.trace[0].0,
        ctl1_ladder_config(saved_level),
        "first resumed step must run at the saved ladder level"
    );
    assert_ne!(r2.trace[0].0.notation(), "[2,2,2,16]");
    std::fs::remove_file(&ckpt).ok();
}

/// The paper-default bfp ladder config at `level`.
fn ctl1_ladder_config(level: usize) -> dsq::schedule::PrecisionConfig {
    DsqControllerConfig::paper_default("bfp").unwrap().ladder[level]
}

#[test]
fn batch_position_rides_checkpoint_trailer() {
    // Crash-salvage checkpoints carry the batch-stream position; the
    // trailer round-trips alongside (and independently of) the
    // schedule one.
    let pos = ResumePosition { epoch: 2, batch: 5 };
    let path = tmpfile("posn.bin");
    checkpoint::save_checkpoint_positioned(&path, &fake_state(), &fake_mm(), None, Some(&pos))
        .unwrap();
    let (state, sched, restored) =
        checkpoint::load_checkpoint_positioned(&path, &fake_mm()).unwrap();
    assert_eq!(state.step, 7);
    assert_eq!(sched, None);
    assert_eq!(restored, Some(pos));

    // Finished-run checkpoints (and every pre-position file) carry no
    // position: resuming them starts a fresh set of epochs.
    checkpoint::save_checkpoint_full(&path, &fake_state(), &fake_mm(), None).unwrap();
    let (_, _, none) = checkpoint::load_checkpoint_positioned(&path, &fake_mm()).unwrap();
    assert_eq!(none, None);
    std::fs::remove_file(&path).ok();
}

/// A [`Task`] that replays the inner task's epoch stream but cuts the
/// producer off after `take` batches — the step loop sees exactly what
/// a run killed between two steps saw, so its state is the true
/// mid-epoch state of the full stream (same seed, same pool, same
/// shuffle), not an approximation from a shorter epoch.
struct TruncatedNmt {
    inner: NmtTask,
    take: usize,
}

impl Task for TruncatedNmt {
    type Batch = Batch;

    fn model(&self) -> &'static str {
        self.inner.model()
    }

    fn describe(&self) -> &'static str {
        "truncated translation training"
    }

    fn batch_producer(
        &self,
        epoch: usize,
        nbatches: usize,
    ) -> Box<dyn FnMut() -> Option<Batch> + Send> {
        let mut produce = self.inner.batch_producer(epoch, nbatches);
        let mut left = self.take;
        Box::new(move || {
            if left == 0 {
                return None;
            }
            left -= 1;
            produce()
        })
    }

    fn val_batches(&self, n: usize) -> Vec<Batch> {
        self.inner.val_batches(n)
    }

    fn push_step_inputs(&self, batch: &Batch, inputs: &mut Vec<HostTensor>) {
        self.inner.push_step_inputs(batch, inputs)
    }

    fn push_eval_inputs(&self, batch: &Batch, inputs: &mut Vec<HostTensor>) {
        self.inner.push_eval_inputs(batch, inputs)
    }

    fn eval_terms(&self, outs: &[HostTensor]) -> dsq::Result<(f64, f64, f64)> {
        self.inner.eval_terms(outs)
    }

    fn final_metric(
        &self,
        state: &ModelState,
        exes: &mut ExeCache,
        final_eval_acc: f64,
        diverged: bool,
    ) -> dsq::Result<Option<TaskMetric>> {
        self.inner.final_metric(state, exes, final_eval_acc, diverged)
    }
}

#[test]
fn mid_epoch_resume_consumes_each_batch_exactly_once_e2e() {
    let Some(dir) = artifacts_dir() else { return };
    let ckpt = tmpfile("midepoch.bin");
    let cfg = TrainerConfig {
        epochs: 1,
        batches_per_epoch: 4,
        val_batches: 2,
        bleu_batches: 0,
        lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 20 },
        variant: Variant::Iwslt,
        ..TrainerConfig::quick(dir.clone())
    };

    // Reference: the uninterrupted 4-batch epoch.
    let mut schedule: Box<dyn Schedule> = Box::new(StaticSchedule(PrecisionConfig::FP32));
    let mut full = Trainer::new(cfg.clone()).unwrap();
    let rf = full.run(schedule.as_mut()).unwrap();
    assert_eq!(rf.steps, 4);

    // "Crash" after step 2: replay the SAME 4-batch epoch stream but
    // stop after two batches, then write the crash-salvage checkpoint a
    // mid-run save would have written — state after step 2, position
    // (epoch 0, batch 2).
    let man = ArtifactManifest::load(&dir).unwrap();
    let (b, s, t, v) = (
        man.nmt.cfg("batch").unwrap(),
        man.nmt.cfg("src_len").unwrap(),
        man.nmt.cfg("tgt_len").unwrap(),
        man.nmt.cfg("vocab").unwrap(),
    );
    let task = TruncatedNmt {
        inner: NmtTask {
            task: TranslationTask::new(TranslationConfig {
                vocab: v as i32,
                src_len: s,
                tgt_len: t,
                variant: Variant::Iwslt,
                seed: 0,
            }),
            batcher: Batcher::new(b, s, t),
            seed: 0,
            bleu_batches: 0,
        },
        take: 2,
    };
    let scfg = SessionConfig {
        artifacts: dir.clone(),
        seed: 0,
        epochs: 1,
        batches_per_epoch: 4,
        lr: cfg.lr.clone(),
        val_batches: 2,
        val_every_steps: 0,
        checkpoint: None,
        init_checkpoint: None,
        checkpoint_every_steps: 0,
        prefetch: 4,
        stash_format: None,
        stash_budget: StashBudget::Unlimited,
        stash_dir: None,
        shard: None,
        trace_dir: None,
    };
    let mut half = Session::new(scfg, task, man).unwrap();
    let mut schedule2: Box<dyn Schedule> = Box::new(StaticSchedule(PrecisionConfig::FP32));
    let rh = half.run(schedule2.as_mut()).unwrap();
    assert_eq!(rh.steps, 2);
    // The truncated run's two steps ARE the reference's first two.
    assert_eq!(&rh.loss_curve[..], &rf.loss_curve[..2]);
    checkpoint::save_checkpoint_positioned(
        &ckpt,
        half.state(),
        &half.manifest().nmt,
        None,
        Some(&ResumePosition { epoch: 0, batch: 2 }),
    )
    .unwrap();

    // Resume: the salvaged run must consume exactly batches 2 and 3 —
    // no batch twice, none skipped. Bit-for-bit that means its two
    // steps land on the reference's step-3/step-4 losses and the final
    // params match the uninterrupted run's exactly.
    let cfg2 = TrainerConfig { init_checkpoint: Some(ckpt.clone()), ..cfg };
    let mut schedule3: Box<dyn Schedule> = Box::new(StaticSchedule(PrecisionConfig::FP32));
    let mut resumed = Trainer::new(cfg2).unwrap();
    let rr = resumed.run(schedule3.as_mut()).unwrap();
    assert_eq!(rr.steps, 4, "resume must finish the epoch, not restart it");
    assert_eq!(
        &rr.loss_curve[..],
        &rf.loss_curve[2..],
        "resumed steps must consume exactly the unconsumed batches"
    );
    assert_eq!(rr.final_val_loss.to_bits(), rf.final_val_loss.to_bits());
    assert_eq!(
        resumed.state().params,
        full.state().params,
        "resumed run must land on the uninterrupted run's state"
    );
    std::fs::remove_file(&ckpt).ok();
}
