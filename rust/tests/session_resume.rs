//! Schedule-state resume: a checkpoint written mid-DSQ-ladder must
//! restore the controller at the saved level/stale count, not silently
//! restart at `[2,2,2,16]`.
//!
//! The trailer round-trip and controller restore are covered without
//! PJRT (fake manifest); the full Session resume runs when `make
//! artifacts` has been done (same gating as `coordinator_e2e`).

use std::path::PathBuf;

use dsq::coordinator::{LrSchedule, Trainer, TrainerConfig};
use dsq::data::Variant;
use dsq::model::{checkpoint, ModelState};
use dsq::runtime::{HostTensor, ModelManifest, ParamSpec};
use dsq::schedule::{DsqController, DsqControllerConfig, Schedule, ScheduleState};

fn fake_mm() -> ModelManifest {
    ModelManifest {
        config: Default::default(),
        params: vec![
            ParamSpec { name: "a.w".into(), shape: vec![2, 3] },
            ParamSpec { name: "b.b".into(), shape: vec![4] },
        ],
        artifacts: Default::default(),
    }
}

fn fake_state() -> ModelState {
    let p = vec![
        HostTensor::f32(vec![2, 3], (0..6).map(|x| x as f32).collect()),
        HostTensor::f32(vec![4], vec![-1.0, 0.5, 2.0, 3.5]),
    ];
    let m = vec![HostTensor::zeros(&[2, 3]), HostTensor::zeros(&[4])];
    ModelState { params: p, m: m.clone(), v: m, step: 7 }
}

fn tmpfile(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsq-resume-{}-{name}", std::process::id()))
}

/// Push a fresh paper-default controller to `level` with flat losses.
fn advance_to_level(ctl: &mut DsqController, level: usize) {
    ctl.observe_validation(5.0); // establishes best_loss
    while ctl.level() < level {
        ctl.observe_validation(5.0);
    }
    assert_eq!(ctl.level(), level);
}

#[test]
fn controller_snapshot_rides_checkpoint_trailer() {
    let mut ctl = DsqController::paper_default("bfp").unwrap();
    advance_to_level(&mut ctl, 2);
    let snap = ctl.snapshot().unwrap();
    assert_eq!(snap.level, 2);

    let path = tmpfile("trailer.bin");
    checkpoint::save_checkpoint_full(&path, &fake_state(), &fake_mm(), Some(&snap)).unwrap();
    let (state, restored) = checkpoint::load_checkpoint_full(&path, &fake_mm()).unwrap();
    assert_eq!(state.step, 7);
    let restored = restored.expect("trailer present");
    assert_eq!(restored, snap);

    // A fresh controller restored from the trailer continues the ladder
    // at level 2 — not at [2,2,2,16].
    let mut resumed = DsqController::paper_default("bfp").unwrap();
    assert_eq!(resumed.current().notation(), "[2,2,2,16]");
    resumed.restore(&restored);
    assert_eq!(resumed.level(), 2);
    assert_eq!(resumed.current(), ctl.current());
    assert_eq!(resumed.describe(), ctl.describe());
    std::fs::remove_file(&path).ok();
}

#[test]
fn stale_count_survives_resume() {
    // Half-way toward a bump (stale 1 of patience 2): after resume, ONE
    // more flat validation must advance the level — the plateau counter
    // carried over.
    let mut ctl = DsqController::paper_default("bfp").unwrap();
    ctl.observe_validation(5.0);
    ctl.observe_validation(5.0); // stale 1
    assert_eq!(ctl.level(), 0);
    let snap = ctl.snapshot().unwrap();
    assert_eq!(snap.stale, 1);

    let path = tmpfile("stale.bin");
    checkpoint::save_checkpoint_full(&path, &fake_state(), &fake_mm(), Some(&snap)).unwrap();
    let (_, restored) = checkpoint::load_checkpoint_full(&path, &fake_mm()).unwrap();
    let mut resumed = DsqController::paper_default("bfp").unwrap();
    resumed.restore(&restored.unwrap());
    resumed.observe_validation(5.0); // stale 2 -> bump
    assert_eq!(resumed.level(), 1);
    std::fs::remove_file(&path).ok();
}

#[test]
fn pre_trailer_checkpoints_resume_with_fresh_schedule() {
    let path = tmpfile("legacy.bin");
    checkpoint::save_checkpoint(&path, &fake_state(), &fake_mm()).unwrap();
    let (_, restored) = checkpoint::load_checkpoint_full(&path, &fake_mm()).unwrap();
    assert_eq!(restored, None, "no trailer = fresh schedule");
    std::fs::remove_file(&path).ok();
}

#[test]
fn restore_is_safe_across_ladder_lengths() {
    // A snapshot from a longer ladder clamps to the shorter one's top.
    let snap = ScheduleState { level: 5, stale: 0, observed: 12, best_loss: 2.0 };
    let cfg =
        DsqControllerConfig::from_specs(0.002, 2, &["bfp:2,2,2,16", "bfp:16,4,4,16"]).unwrap();
    let mut short = DsqController::new(cfg);
    short.restore(&snap);
    assert_eq!(short.level(), 1);
    assert!(short.at_top());
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn session_resumes_mid_ladder_e2e() {
    let Some(dir) = artifacts_dir() else { return };
    let ckpt = std::env::temp_dir().join(format!("dsq-resume-e2e-{}.bin", std::process::id()));
    let cfg = TrainerConfig {
        epochs: 2,
        batches_per_epoch: 4,
        val_batches: 2,
        bleu_batches: 0,
        lr: LrSchedule::InverseSqrt { peak_lr: 3e-3, warmup_steps: 20 },
        variant: Variant::Iwslt,
        checkpoint: Some(ckpt.clone()),
        ..TrainerConfig::quick(dir.clone())
    };

    // Run 1 under a controller already mid-ladder (level 2).
    let mut ctl1 = DsqController::paper_default("bfp").unwrap();
    advance_to_level(&mut ctl1, 2);
    let mut trainer1 = Trainer::new(cfg.clone()).unwrap();
    let r1 = trainer1.run(&mut ctl1).unwrap();
    let saved_level = ctl1.level(); // >= 2, monotone
    assert!(saved_level >= 2);
    assert_eq!(r1.trace[0].0, ctl1_ladder_config(2));

    // Run 2: a FRESH controller plus --init-checkpoint must resume at
    // the saved level — its very first step runs at that config, not at
    // the ladder bottom.
    let cfg2 = TrainerConfig {
        checkpoint: None,
        init_checkpoint: Some(ckpt.clone()),
        ..cfg
    };
    let mut ctl2 = DsqController::paper_default("bfp").unwrap();
    assert_eq!(ctl2.level(), 0);
    let mut trainer2 = Trainer::new(cfg2).unwrap();
    let r2 = trainer2.run(&mut ctl2).unwrap();
    assert_eq!(r2.steps, r1.steps + 8);
    assert!(ctl2.level() >= saved_level, "ladder went backwards across resume");
    assert_eq!(
        r2.trace[0].0,
        ctl1_ladder_config(saved_level),
        "first resumed step must run at the saved ladder level"
    );
    assert_ne!(r2.trace[0].0.notation(), "[2,2,2,16]");
    std::fs::remove_file(&ckpt).ok();
}

/// The paper-default bfp ladder config at `level`.
fn ctl1_ladder_config(level: usize) -> dsq::schedule::PrecisionConfig {
    DsqControllerConfig::paper_default("bfp").unwrap().ladder[level]
}
