//! Drift-injection tests for `dsq lint`: each fixture copies the real
//! contract files into a temp tree, injects exactly the drift class a
//! rule exists to catch, and asserts the lint (a) exits nonzero and
//! (b) names the right rule, file and line. The clean-tree test pins
//! the other direction: HEAD itself must lint clean, so a rule that
//! starts firing spuriously fails here before it fails CI.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};

use dsq::analysis::{self, run_lint, Finding};

/// The repo root: the bench/test cwd is `rust/`, so walk up from the
/// manifest dir.
fn repo_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    analysis::find_root(&manifest).expect("repo root above CARGO_MANIFEST_DIR")
}

/// Fresh scratch dir per fixture (no tempfile crate offline).
fn scratch(tag: &str) -> PathBuf {
    static N: AtomicU32 = AtomicU32::new(0);
    let dir = std::env::temp_dir().join(format!(
        "dsq-lint-fixture-{}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed),
        tag
    ));
    if dir.exists() {
        fs::remove_dir_all(&dir).expect("clear stale fixture dir");
    }
    fs::create_dir_all(&dir).expect("create fixture dir");
    dir
}

/// Copy the lint's contract files from the real repo into `dst`. The
/// resulting tree is the minimal input `run_lint` accepts; the scoped
/// rules (panic hygiene, locks) additionally see whatever the fixture
/// adds under `rust/src/stash/`.
fn copy_contract_files(root: &Path, dst: &Path) {
    const FILES: &[&str] = &[
        "rust/src/quant/format.rs",
        "rust/src/quant/packed.rs",
        "rust/src/costmodel/formats.rs",
        "rust/src/model/checkpoint.rs",
        "rust/src/coordinator/cli.rs",
        "rust/src/coordinator/session.rs",
        "rust/src/runtime/artifact.rs",
        "rust/src/stash/exchange.rs",
        "rust/benches/quantizer_hotpath.rs",
        "rust/benches/stash_store.rs",
        "python/compile/layers.py",
        "python/compile/aot.py",
        "python/compile/kernels/ref.py",
        "rust/src/analysis/mod.rs",
        "ROADMAP.md",
    ];
    for rel in FILES {
        let to = dst.join(rel);
        fs::create_dir_all(to.parent().unwrap()).expect("mkdir");
        fs::copy(root.join(rel), &to).unwrap_or_else(|e| panic!("copy {rel}: {e}"));
    }
}

/// Rewrite one file in the fixture tree by exact substring replacement,
/// panicking if the needle is gone (so a refactor of the real file
/// breaks the fixture loudly instead of testing nothing).
fn rewrite(dst: &Path, rel: &str, from: &str, to: &str) {
    let path = dst.join(rel);
    let text = fs::read_to_string(&path).expect("read fixture file");
    assert!(
        text.contains(from),
        "fixture needle {from:?} not found in {rel} — update the drift test"
    );
    fs::write(&path, text.replace(from, to)).expect("write fixture file");
}

fn findings_for<'a>(fs: &'a [Finding], rule: &str) -> Vec<&'a Finding> {
    fs.iter().filter(|f| f.rule == rule).collect()
}

#[test]
fn head_tree_lints_clean() {
    let report = run_lint(&repo_root()).expect("lint runs on HEAD");
    assert!(
        report.findings.is_empty(),
        "HEAD must lint clean; got:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    assert_eq!(report.rules_run, 7);
}

#[test]
fn fixture_tree_lints_clean_unmodified() {
    // The copy itself must be clean, or every drift assertion below
    // would be testing copy artifacts rather than the injected drift.
    let dst = scratch("clean");
    copy_contract_files(&repo_root(), &dst);
    let report = run_lint(&dst).expect("lint runs on fixture");
    assert!(
        report.findings.is_empty(),
        "unmodified fixture must lint clean; got:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn skewed_python_mode_constant_is_a_qcfg_finding() {
    // The PR-4 bug class: python's BFP mode scalar silently disagreeing
    // with rust's. layers.py carries `MODE_BFP = 2.0`; skew it.
    let dst = scratch("mode-skew");
    copy_contract_files(&repo_root(), &dst);
    rewrite(&dst, "python/compile/layers.py", "MODE_BFP = 2.0", "MODE_BFP = 7.0");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "qcfg_sync");
    assert!(
        !hits.is_empty(),
        "mode skew must be a qcfg_sync finding; all findings:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    let named = hits.iter().any(|f| {
        f.file == "python/compile/layers.py" && f.line > 0 && f.message.contains("bfp")
    });
    assert!(named, "finding must name layers.py, a line, and the bfp family: {hits:?}");
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn deleted_codec_arm_is_a_coverage_finding() {
    // Remove the Bfp arm from `codec_tag` — the registry row survives,
    // so the coverage matrix has a hole the codec can no longer fill.
    let dst = scratch("codec-arm");
    copy_contract_files(&repo_root(), &dst);
    let path = dst.join("rust/src/quant/packed.rs");
    let text = fs::read_to_string(&path).expect("read packed.rs");
    let filtered: Vec<&str> = text
        .lines()
        .filter(|l| !(l.contains("FormatSpec::Bfp") && l.contains("=> 3")))
        .collect();
    assert!(
        filtered.len() < text.lines().count(),
        "expected to delete the Bfp codec_tag arm — update the drift test"
    );
    fs::write(&path, filtered.join("\n")).expect("write packed.rs");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "registry_coverage");
    assert!(
        hits.iter().any(|f| f.file == "rust/src/quant/packed.rs"
            && f.line > 0
            && f.message.to_lowercase().contains("bfp")),
        "missing codec arm must be a registry_coverage finding naming packed.rs + bfp:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn duplicated_checkpoint_magic_is_a_magic_finding() {
    // Point the schedule writer at the checkpoint magic: the literal
    // b"DSQCKPT2" is now const-defined twice, and b"DSQSCHD1" vanishes
    // from the tree entirely — both are magic_constants findings.
    let dst = scratch("magic-dup");
    copy_contract_files(&repo_root(), &dst);
    rewrite(&dst, "rust/src/model/checkpoint.rs", "b\"DSQSCHD1\"", "b\"DSQCKPT2\"");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "magic_constants");
    assert!(
        hits.iter().any(|f| f.file == "rust/src/model/checkpoint.rs"
            && f.line > 0
            && f.message.contains("DSQCKPT2")),
        "duplicated magic must be a magic_constants finding naming checkpoint.rs:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn unannotated_hot_path_unwrap_is_a_panic_finding_and_allow_clears_it() {
    let dst = scratch("panic");
    copy_contract_files(&repo_root(), &dst);
    let stash = dst.join("rust/src/stash/prefetch.rs");
    fs::create_dir_all(stash.parent().unwrap()).expect("mkdir stash");
    fs::write(
        &stash,
        "pub fn peek(v: &[u8]) -> u8 {\n    *v.first().unwrap()\n}\n",
    )
    .expect("write fixture stash file");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "panic_hygiene");
    assert!(
        hits.iter()
            .any(|f| f.file == "rust/src/stash/prefetch.rs" && f.line == 2),
        "hot-path unwrap must be a panic_hygiene finding at line 2:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );

    // The escape hatch, with a real rule name and reason, clears it.
    // (The directive is assembled at runtime so the linter scanning
    // THIS file on HEAD never sees it as a live escape.)
    let allow = format!("// dsq-lint{}", ": allow(panic_hygiene, fixture proves the escape works)");
    fs::write(
        &stash,
        format!("pub fn peek(v: &[u8]) -> u8 {{\n    {allow}\n    *v.first().unwrap()\n}}\n"),
    )
    .expect("rewrite fixture stash file");
    let report = run_lint(&dst).expect("lint runs");
    assert!(
        report.findings.is_empty(),
        "annotated unwrap must lint clean:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn typoed_allow_rule_is_itself_a_finding() {
    let dst = scratch("escape");
    copy_contract_files(&repo_root(), &dst);
    let stash = dst.join("rust/src/stash/prefetch.rs");
    fs::create_dir_all(stash.parent().unwrap()).expect("mkdir stash");
    // Assembled at runtime so the linter scanning THIS file on HEAD
    // never sees the (deliberately) typo'd escape.
    let allow = format!("// dsq-lint{}", ": allow(panic_hygeine, typo'd rule never suppresses)");
    fs::write(
        &stash,
        format!("pub fn peek(v: &[u8]) -> u8 {{\n    {allow}\n    *v.first().unwrap()\n}}\n"),
    )
    .expect("write fixture stash file");
    let report = run_lint(&dst).expect("lint runs");
    let escape = findings_for(&report.findings, "lint_escape");
    let panic = findings_for(&report.findings, "panic_hygiene");
    assert!(!escape.is_empty(), "typo'd allow must be a lint_escape finding");
    assert!(!panic.is_empty(), "typo'd allow must not suppress the underlying finding");
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn inverted_lock_order_is_a_lock_discipline_finding() {
    // Prove the rule fires on the classic AB/BA shape in a fresh stash
    // module, independent of the real exchange mutexes.
    let dst = scratch("locks");
    copy_contract_files(&repo_root(), &dst);
    let stash = dst.join("rust/src/stash/prefetch.rs");
    fs::create_dir_all(stash.parent().unwrap()).expect("mkdir stash");
    fs::write(
        &stash,
        "use std::sync::Mutex;\n\
         pub struct P { lru: Mutex<u32>, budget: Mutex<u32> }\n\
         impl P {\n\
             pub fn evict(&self) -> u32 {\n\
                 let a = self.lru.lock().unwrap();\n\
                 let b = self.budget.lock().unwrap();\n\
                 *a + *b\n\
             }\n\
             pub fn prefetch(&self) -> u32 {\n\
                 let b = self.budget.lock().unwrap();\n\
                 let a = self.lru.lock().unwrap();\n\
                 *a + *b\n\
             }\n\
         }\n",
    )
    .expect("write fixture stash file");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "lock_discipline");
    assert!(
        hits.iter().any(|f| f.file == "rust/src/stash/prefetch.rs"
            && f.message.contains("lru")
            && f.message.contains("budget")),
        "AB/BA lock order must be a lock_discipline finding naming both mutexes:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn inverted_exchange_mutex_order_is_a_lock_discipline_finding() {
    // The exchange's real lock-order invariant (PR 7): every function
    // takes the `ring` post board strictly before the `comms` traffic
    // meter. Append a pair of probe functions to the *copied* real
    // exchange.rs that acquire the two actual mutexes in both orders —
    // the rule must flag the AB/BA pair by the real field names. (The
    // lint is lexical, so the appended probes need not compile against
    // the private types.)
    let dst = scratch("exchange-locks");
    copy_contract_files(&repo_root(), &dst);
    let path = dst.join("rust/src/stash/exchange.rs");
    let mut text = fs::read_to_string(&path).expect("read copied exchange.rs");
    assert!(
        text.contains("ring") && text.contains("comms"),
        "exchange.rs no longer names the ring/comms mutexes — update the drift test"
    );
    text.push_str(
        "\nfn drift_probe_ab(core: &Core) {\n\
         \x20   let _a = core.ring.lock();\n\
         \x20   let _b = core.comms.lock();\n\
         }\n\
         fn drift_probe_ba(core: &Core) {\n\
         \x20   let _b = core.comms.lock();\n\
         \x20   let _a = core.ring.lock();\n\
         }\n",
    );
    fs::write(&path, text).expect("write fixture exchange.rs");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "lock_discipline");
    assert!(
        hits.iter().any(|f| f.file == "rust/src/stash/exchange.rs"
            && f.message.contains("ring")
            && f.message.contains("comms")),
        "AB/BA exchange mutex order must be a lock_discipline finding naming ring + comms:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn cross_function_lock_inversion_needs_the_call_graph() {
    // The interprocedural upgrade's load-bearing case: lock A in `f`,
    // call `g`, lock B in `g`; elsewhere B then A in one body. No single
    // function acquires both locks in the A→B direction, so the
    // superseded per-function scan must pass the tree — and the
    // call-graph rule must report it with both call paths named.
    let dst = scratch("xfn-locks");
    copy_contract_files(&repo_root(), &dst);
    let stash = dst.join("rust/src/stash/prefetch.rs");
    fs::create_dir_all(stash.parent().unwrap()).expect("mkdir stash");
    fs::write(
        &stash,
        "use std::sync::Mutex;\n\
         pub struct P { lru: Mutex<u32>, budget: Mutex<u32> }\n\
         fn drift_take_budget(p: &P) {\n\
         \x20   let _b = p.budget.lock();\n\
         }\n\
         fn drift_ab(p: &P) {\n\
         \x20   let _a = p.lru.lock();\n\
         \x20   drift_take_budget(p);\n\
         }\n\
         fn drift_ba(p: &P) {\n\
         \x20   let _b = p.budget.lock();\n\
         \x20   let _a = p.lru.lock();\n\
         }\n",
    )
    .expect("write fixture stash file");

    let tree = analysis::Tree::load(&dst).expect("fixture tree loads");
    let mut old = Vec::new();
    analysis::locks::check_per_function(&tree, &mut old);
    assert!(
        old.is_empty(),
        "the split inversion must be invisible per-function (that is the point): {:?}",
        old.iter().map(ToString::to_string).collect::<Vec<_>>()
    );

    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "lock_discipline");
    assert!(
        hits.iter().any(|f| f.file == "rust/src/stash/prefetch.rs"
            && f.message.contains("lru")
            && f.message.contains("budget")
            && f.message.contains("drift_ab")
            && f.message.contains("drift_take_budget")
            && f.message.contains("drift_ba")),
        "cross-function AB/BA must be a lock_discipline finding naming both call paths:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn recv_while_holding_ring_is_a_blocking_finding() {
    // The PR-7 barrier-deadlock class: a channel park while holding the
    // exchange's `ring` mutex — directly, and through a helper so the
    // finding carries the call path.
    let dst = scratch("blocking");
    copy_contract_files(&repo_root(), &dst);
    let path = dst.join("rust/src/stash/exchange.rs");
    let mut text = fs::read_to_string(&path).expect("read copied exchange.rs");
    assert!(
        text.contains("ring"),
        "exchange.rs no longer names the ring mutex — update the drift test"
    );
    text.push_str(
        "\nfn drift_recv_helper(rx: &Receiver) {\n\
         \x20   let _ = rx.recv();\n\
         }\n\
         fn drift_recv_under_ring(core: &Core, rx: &Receiver) {\n\
         \x20   let _g = core.ring.lock();\n\
         \x20   let _ = rx.recv();\n\
         \x20   drift_recv_helper(rx);\n\
         }\n",
    );
    fs::write(&path, text).expect("write fixture exchange.rs");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "blocking_under_lock");
    assert!(
        hits.iter().any(|f| f.file == "rust/src/stash/exchange.rs"
            && f.message.contains("'ring'")
            && f.message.contains("channel recv")),
        "recv while holding ring must be a blocking_under_lock finding:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    assert!(
        hits.iter().any(|f| f.message.contains("drift_recv_helper")),
        "the through-a-helper park must surface with the call path named:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn dropped_roadmap_rule_row_is_a_lint_meta_finding() {
    // The linter's own docs are an invariant too: retire a rule row
    // from ROADMAP's "Static analysis" table (and plant an undocumented
    // one) and the lint must fail its own build.
    let dst = scratch("meta");
    copy_contract_files(&repo_root(), &dst);
    rewrite(&dst, "ROADMAP.md", "| `magic_constants` |", "| `zzz_retired_rule` |");
    let report = run_lint(&dst).expect("lint runs");
    let hits = findings_for(&report.findings, "lint_meta");
    assert!(
        hits.iter().any(|f| f.file == "ROADMAP.md" && f.message.contains("magic_constants")),
        "the missing row must be a lint_meta finding naming the rule:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    assert!(
        hits.iter().any(|f| f.message.contains("zzz_retired_rule")),
        "a documented-but-unimplemented rule must also be a finding:\n{}",
        report.findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
    fs::remove_dir_all(&dst).ok();
}

#[test]
fn missing_required_input_fails_loudly() {
    let dst = scratch("missing");
    copy_contract_files(&repo_root(), &dst);
    fs::remove_file(dst.join("python/compile/layers.py")).expect("remove layers.py");
    let err = run_lint(&dst).expect_err("lint must refuse a tree missing a contract file");
    assert!(
        err.to_string().contains("layers.py"),
        "error must name the missing input: {err}"
    );
    fs::remove_dir_all(&dst).ok();
}

/// End-to-end exit codes through the real binary (the CI entry point).
/// Skipped when the integration-test env doesn't expose the binary.
#[test]
fn cli_lint_exit_codes() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_dsq") else { return };
    let root = repo_root();
    let ok = std::process::Command::new(bin)
        .args(["lint", "--root"])
        .arg(&root)
        .output()
        .expect("run dsq lint");
    assert!(
        ok.status.success(),
        "dsq lint on HEAD must exit 0; stdout:\n{}\nstderr:\n{}",
        String::from_utf8_lossy(&ok.stdout),
        String::from_utf8_lossy(&ok.stderr)
    );
    assert!(String::from_utf8_lossy(&ok.stdout).contains("clean"));

    // A drifted tree exits 1 (not 2: findings are not a config error).
    let dst = scratch("cli");
    copy_contract_files(&root, &dst);
    rewrite(&dst, "python/compile/layers.py", "MODE_BFP = 2.0", "MODE_BFP = 7.0");
    let bad = std::process::Command::new(bin)
        .args(["lint", "--root"])
        .arg(&dst)
        .output()
        .expect("run dsq lint on fixture");
    assert_eq!(bad.status.code(), Some(1), "findings must exit 1");
    assert!(String::from_utf8_lossy(&bad.stdout).contains("lint[qcfg_sync]"));
    fs::remove_dir_all(&dst).ok();
}
