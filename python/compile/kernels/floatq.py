"""Pallas kernel: low-bit float (``e<E>m<M>``) fake quantization.

The float family (FP8 E4M3/E5M2, bf16 = e8m7, fp16 = e5m10) quantizes
every element against its own exponent — no reduction at all — so the
kernel is a pure elementwise map: decode the packed ``100*E + M`` grid
code, clip the element exponent to the format range, round the
significand half-to-even on the power-of-two step, saturate. Tensors
too large for the single-block budget fall back to the jnp oracle
(same numerics, XLA-fused), mirroring fixed.py.

Semantics identical to ``ref.float_quantize_ref``; pytest asserts
bit-equality.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EXP_MAX, EXP_MIN, exact_pow2, float_quantize_ref

# Single-block budget: input + output f32 tiles (see bfp.py for rationale).
_SINGLE_BLOCK_LIMIT = (4 * 1024 * 1024) // (4 * 2)


def _float_kernel(c_ref, x_ref, o_ref):
    x = x_ref[...]
    # Explicit input FTZ, matching ref.float_quantize_ref / rust ftz()
    # (exact zeros excluded so -0.0 keeps its sign).
    ftz_mask = jnp.logical_and(x != 0.0, jnp.abs(x) < jnp.float32(2.0**-126))
    x = jnp.where(ftz_mask, jnp.float32(0.0), x)
    code = c_ref[0, 0]
    ebits = jnp.floor(code / 100.0)
    m = code - ebits * 100.0
    bias = exact_pow2(ebits - 1.0) - 1.0
    e_min = 1.0 - bias
    e_max = bias
    maxval = exact_pow2(e_max) * (2.0 - exact_pow2(-m))
    xbits = jax.lax.bitcast_convert_type(x, jnp.int32)
    e = (((xbits >> 23) & 0xFF) - 127).astype(jnp.float32)
    e = jnp.clip(e, e_min, e_max)
    # exact_pow2 + clamp to the normal range (XLA exp2 inexact; FTZ).
    step = exact_pow2(jnp.clip(e - m, EXP_MIN, EXP_MAX))
    mag = jnp.round(x / step)
    o_ref[...] = jnp.clip(mag * step, -maxval, maxval)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _float_quantize_2d(x: jax.Array, code: jax.Array, interpret: bool = True) -> jax.Array:
    rows, cols = x.shape
    c2d = code.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _float_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(c2d, x)


def float_quantize(x: jax.Array, code, interpret: bool = True) -> jax.Array:
    """``e<E>m<M>`` float fake quantization (any shape); ``code`` packs
    the grid parameters as ``100*E + M`` (``ref.float_code``)."""
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(code, jnp.float32)
    if x.size > _SINGLE_BLOCK_LIMIT or x.ndim == 0:
        return float_quantize_ref(x, c)
    n = x.shape[-1]
    flat = x.reshape(-1, n)
    q = _float_quantize_2d(flat, c, interpret=interpret)
    return q.reshape(x.shape)
