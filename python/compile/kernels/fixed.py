"""Pallas kernel: dynamic per-tensor fixed-point fake quantization.

The fixed-point baseline ("the standard 16-bit fixed-point widely used in
on-device learning", paper §1/§4) shares ONE exponent across the whole
tensor. That global reduction makes it a two-stage kernel on real
hardware; here the tensor sizes DSQ stashes (≤ a few MiB) fit a single
VMEM-resident block, so the kernel runs as one grid step: global |max| →
shared exponent → round/clamp/dequant. Tensors too large for the budget
fall back to the jnp oracle (same numerics, XLA-fused) — documented in
DESIGN.md §Perf.

Semantics identical to ``ref.fixed_quantize_ref``; pytest asserts
bit-equality.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EXP_MAX, EXP_MIN, PASSTHROUGH_BITS, exact_pow2, fixed_quantize_ref

# Single-block budget: input + output f32 tiles (see bfp.py for rationale).
_SINGLE_BLOCK_LIMIT = (4 * 1024 * 1024) // (4 * 2)


def _fixed_kernel(b_ref, x_ref, o_ref):
    x = x_ref[...]
    b = b_ref[0, 0]
    amax = jnp.max(jnp.abs(x))
    ebits = jax.lax.bitcast_convert_type(amax, jnp.int32)
    e = (((ebits >> 23) & 0xFF) - 127).astype(jnp.float32)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    # exact_pow2 + clamp to normal range (XLA exp2 inexact; FTZ), see ref.py.
    step = exact_pow2(jnp.clip(e - b + 2.0, EXP_MIN, EXP_MAX))
    maxmag = exact_pow2(b - 1.0) - 1.0
    mag = jnp.clip(jnp.round(x / step), -maxmag, maxmag)
    q = jnp.where(amax > 0.0, mag * step, 0.0)
    o_ref[...] = jnp.where(b >= PASSTHROUGH_BITS, x, q)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fixed_quantize_2d(x: jax.Array, bits: jax.Array, interpret: bool = True) -> jax.Array:
    rows, cols = x.shape
    b2d = bits.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _fixed_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(b2d, x)


def fixed_quantize(x: jax.Array, bits, interpret: bool = True) -> jax.Array:
    """Per-tensor dynamic fixed-point fake quantization (any shape)."""
    x = jnp.asarray(x, jnp.float32)
    b = jnp.asarray(bits, jnp.float32)
    if x.size > _SINGLE_BLOCK_LIMIT or x.ndim == 0:
        return fixed_quantize_ref(x, b)
    n = x.shape[-1]
    flat = x.reshape(-1, n)
    q = _fixed_quantize_2d(flat, b, interpret=interpret)
    return q.reshape(x.shape)
