"""Pallas kernel: fused BFP-quantized GEMM (quantize tiles -> MXU matmul).

This is the kernel a real TPU deployment of DSQ would run for every GEMM:
HBM tiles of ``x`` and ``w`` are staged into VMEM, BFP fake-quantized
in-register (boxes along the contraction axis), multiplied on the MXU in
f32, and accumulated into a VMEM accumulator across the K grid axis.

Key structural points (DESIGN.md §Hardware-Adaptation):

* the bounding box (16) lies along K, and the K block size is a multiple
  of BOX, so boxes never straddle tiles — tile-local quantization is
  bit-identical to whole-tensor quantization (asserted in pytest);
* ``x`` is quantized row-wise (boxes along K) and ``w`` column-wise: for
  ``w`` we box along its first axis (K) by transposing the tile view, the
  layout MSFP hardware uses so both GEMM operands share exponents along
  the dot-product dimension;
* accumulation is full f32 (wide accumulators — the paper's cost model
  likewise charges mantissa-width multipliers + wide adders).

Used by benches and tests as the standalone hot path; the L2 model uses
``bfp_quantize`` + XLA dot so the custom_vjp can control the stash
separately (see layers.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BOX, EXP_MAX, EXP_MIN, PASSTHROUGH_BITS, exact_pow2


def _quant_boxed(t: jax.Array, m: jax.Array, box: int) -> jax.Array:
    """BFP fake-quantize a 2D tile with boxes along the LAST axis."""
    r, c = t.shape
    boxed = t.reshape(r, c // box, box)
    amax = jnp.max(jnp.abs(boxed), axis=-1, keepdims=True)
    ebits = jax.lax.bitcast_convert_type(amax, jnp.int32)
    e = (((ebits >> 23) & 0xFF) - 127).astype(jnp.float32)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    # exact_pow2 + clamp to normal range (XLA exp2 inexact; FTZ), see ref.py.
    step = exact_pow2(jnp.clip(e - m + 2.0, EXP_MIN, EXP_MAX))
    maxmag = exact_pow2(m - 1.0) - 1.0
    mag = jnp.clip(jnp.round(boxed / step), -maxmag, maxmag)
    q = jnp.where(amax > 0.0, mag * step, 0.0).reshape(r, c)
    return jnp.where(m >= PASSTHROUGH_BITS, t, q)


def _qgemm_kernel(bx_ref, bw_ref, x_ref, w_ref, o_ref, *, box: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = _quant_boxed(x_ref[...], bx_ref[0, 0], box)  # (bm, bk): boxes on K
    # w tile is (bk, bn); boxes must lie along K -> transpose, box, restore.
    wq = _quant_boxed(w_ref[...].T, bw_ref[0, 0], box).T
    o_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def _pick(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (tile-size helper)."""
    best = 1
    for cand in range(1, min(dim, pref) + 1):
        if dim % cand == 0:
            best = cand
    return best


@functools.partial(jax.jit, static_argnames=("interpret", "bm", "bn", "bk"))
def bfp_qgemm(
    x: jax.Array,
    w: jax.Array,
    bits_x: jax.Array,
    bits_w: jax.Array,
    interpret: bool = True,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """``q(x) @ q(w)`` with BFP boxes along the contraction axis.

    Requires ``x.shape = (M, K)``, ``w.shape = (K, N)``, ``K % BOX == 0``.
    Block sizes are clipped to divisors of the problem (K blocks stay BOX
    multiples).
    """
    (m, k), (k2, n) = x.shape, w.shape
    assert k == k2 and k % BOX == 0, (x.shape, w.shape)
    bm = _pick(m, bm)
    bn = _pick(n, bn)
    bk = _pick(k // BOX, max(1, bk // BOX)) * BOX
    nk = k // bk
    bx2 = jnp.asarray(bits_x, jnp.float32).reshape(1, 1)
    bw2 = jnp.asarray(bits_w, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_qgemm_kernel, box=BOX, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(bx2, bw2, x, w)
