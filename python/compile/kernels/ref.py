"""Pure-jnp reference oracles for the DSQ quantizers.

These are the ground truth the Pallas kernels (bfp.py, qgemm.py) and the
rust mirrors (rust/src/quant/) are validated against. The math is written
so that a bit-exact rust implementation is possible:

* shared/box exponents are extracted from IEEE-754 bit patterns
  (``floor(log2(|x|))`` for normal floats) instead of ``log2`` — exact and
  platform independent;
* scales are powers of two computed with ``exp2`` of integer-valued floats
  — exact in f32 for the exponent ranges we use;
* rounding is round-half-to-even (``jnp.round`` / rust
  ``f32::round_ties_even``).

Conventions (MSFP-style Block Floating Point, Darvish Rouhani et al. 2020):

* bounding box = ``BOX`` (16) consecutive elements along the last axis;
* per box: shared exponent ``e = floor(log2(max|x|))`` clamped to the 8-bit
  biased-exponent range ``[-126, 127]``;
* each element keeps a sign + ``(m-1)``-bit magnitude: with ``m`` total
  mantissa bits the quantization step is ``2^(e - m + 2)`` and magnitudes
  clamp to ``2^(m-1) - 1``;
* ``m >= 25`` (wider than f32's 24-bit significand) short-circuits to the
  identity, which is how "32-bit"/fp32 rows are expressed at runtime;
* all-zero boxes quantize to zero.

Dynamic fixed point uses the same element rule with a single *per-tensor*
exponent — its per-tensor (vs per-box) scaling is exactly the weakness the
paper's Stashing(Fixed) rows expose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BOX = 16  # bounding-box size (elements sharing one exponent)
EXP_BITS = 8  # shared-exponent width; gives the [-126, 127] clamp below
EXP_MIN = -126.0
EXP_MAX = 127.0
PASSTHROUGH_BITS = 25.0  # m >= 25 cannot lose f32 information -> identity


def floor_log2(x: jax.Array) -> jax.Array:
    """floor(log2(x)) for x > 0, exact, via the IEEE-754 exponent field.

    Subnormals (< 2^-126) are mapped to -127 which the callers treat like
    zero (they clamp the shared exponent to EXP_MIN and the magnitudes all
    round to 0 at any mantissa width we support).
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    return e.astype(jnp.float32)


def exact_pow2(k: jax.Array) -> jax.Array:
    """Exact 2^k for integer-valued f32 ``k`` via bit construction.

    XLA's ``exp2`` is approximate (CPU lowers it through ``exp(k·ln2)``;
    e.g. ``exp2(23.0)`` returns 8388603.5, 7 ulp off), which breaks the
    bit-exactness contract with the rust mirror. Powers of two are instead
    assembled directly in the exponent field, including the subnormal
    range (k ≥ -149); k below that underflows to 0.
    """
    ki = jnp.clip(k, -200.0, 127.0).astype(jnp.int32)
    normal = jax.lax.bitcast_convert_type((ki + 127) << 23, jnp.float32)
    sub_shift = jnp.clip(ki + 149, 0, 30)
    sub = jax.lax.bitcast_convert_type(
        jnp.left_shift(jnp.int32(1), sub_shift), jnp.float32
    )
    return jnp.where(ki >= -126, normal, jnp.where(ki >= -149, sub, 0.0))


def _quantize_with_exponent(x: jax.Array, e: jax.Array, m: jax.Array) -> jax.Array:
    """Sign + (m-1)-bit magnitude quantization against shared exponent e.

    ``e`` must broadcast against ``x``; ``m`` is a scalar (runtime) mantissa
    width in bits. Returns the dequantized (fake-quantized) f32 values.
    """
    m = jnp.asarray(m, jnp.float32)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    # Quantization step 2^(e - m + 2); max magnitude 2^(m-1) - 1 so that the
    # largest representable value is ~2^(e+1), covering amax in [2^e, 2^(e+1)).
    # exact_pow2, not exp2: XLA's exp2 is off by ulps (see its docstring).
    # The step exponent is clamped to the normal-f32 range: XLA CPU runs
    # with FTZ, so a subnormal step would flush to 0 (and real MSFP
    # hardware has no subnormal support either).
    step = exact_pow2(jnp.clip(e - m + 2.0, EXP_MIN, EXP_MAX))
    maxmag = exact_pow2(m - 1.0) - 1.0
    mag = jnp.round(x / step)
    mag = jnp.clip(mag, -maxmag, maxmag)
    return mag * step


def bfp_quantize_ref(x: jax.Array, mbits) -> jax.Array:
    """Block-floating-point fake quantization, boxes along the last axis.

    The last axis is zero-padded to a multiple of BOX, boxed, quantized and
    sliced back — matching the physical layout of an MSFP tensor.
    """
    x = jnp.asarray(x, jnp.float32)
    m = jnp.asarray(mbits, jnp.float32)
    orig_shape = x.shape
    n = x.shape[-1] if x.ndim else 1
    flat = x.reshape(-1, n) if x.ndim else x.reshape(1, 1)
    padded = flat.shape[-1]
    pad = (-padded) % BOX
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    boxed = flat.reshape(flat.shape[0], -1, BOX)
    amax = jnp.max(jnp.abs(boxed), axis=-1, keepdims=True)
    e = floor_log2(amax)
    q = _quantize_with_exponent(boxed, e, m)
    q = jnp.where(amax > 0.0, q, 0.0)
    q = q.reshape(flat.shape)
    if pad:
        q = q[:, :padded]
    q = q.reshape(orig_shape)
    return jnp.where(m >= PASSTHROUGH_BITS, x, q)


def fixed_quantize_ref(x: jax.Array, bits) -> jax.Array:
    """Dynamic per-tensor fixed-point fake quantization.

    One shared exponent for the whole tensor (chosen from the global max),
    sign + (bits-1)-bit magnitude. This is the strong variant of the 16-bit
    fixed-point baseline used in on-device learning; its global scaling is
    what makes aggressive widths fail on heavy-tailed tensors (Table 5).
    """
    x = jnp.asarray(x, jnp.float32)
    b = jnp.asarray(bits, jnp.float32)
    amax = jnp.max(jnp.abs(x))
    e = floor_log2(amax)
    q = _quantize_with_exponent(x, e, b)
    q = jnp.where(amax > 0.0, q, 0.0)
    return jnp.where(b >= PASSTHROUGH_BITS, x, q)


def float_code(exp_bits: int, man_bits: int) -> float:
    """Pack a float format's grid parameters into the qcfg width field
    (``100*E + M`` — the encoding ``FormatSpec::qcfg_bits`` emits)."""
    return float(100 * exp_bits + man_bits)


def float_quantize_ref(x: jax.Array, code) -> jax.Array:
    """Low-bit float fake quantization (``e<E>m<M>``: FP8 E4M3/E5M2,
    bf16 = e8m7, fp16 = e5m10) — per-element exponents, no reduction.

    ``code`` packs the grid as ``100*E + M`` (see :func:`float_code`).
    IEEE-style grid with bias ``2^(E-1) - 1``: subnormal support below
    the minimum normal binade, saturating overflow at
    ``2^e_max * (2 - 2^-M)`` (±inf saturate too; NaN propagates). The
    step exponent is clamped to the normal-f32 range like everywhere
    else (XLA FTZ would flush a subnormal step), which for wide-exponent
    formats (e8m7) bottoms the grid out on a 2^-126 step; f32-subnormal
    *inputs* are flushed to zero explicitly (not just via XLA's FTZ
    flag — at E=8 the per-element exponent is sensitive enough that the
    mirror contract must not depend on a platform setting). Mirrors
    ``rust/src/quant/float.rs`` op for op.
    """
    x = jnp.asarray(x, jnp.float32)
    # Explicit FTZ on inputs (rust ftz()): exact zeros are excluded so
    # -0.0 keeps its sign like the rust mirror; |NaN| < c is False, so
    # NaN rides through. (For flushed *subnormal* inputs the sign of the
    # resulting zero is not part of the contract: XLA's FTZ flag may
    # rewrite the input to a signed zero before this mask sees it, and
    # f32 == — the asserted mirror relation — cannot observe it.)
    ftz_mask = jnp.logical_and(x != 0.0, jnp.abs(x) < jnp.float32(2.0**-126))
    x = jnp.where(ftz_mask, jnp.float32(0.0), x)
    code = jnp.asarray(code, jnp.float32)
    ebits = jnp.floor(code / 100.0)
    m = code - ebits * 100.0
    bias = exact_pow2(ebits - 1.0) - 1.0
    e_min = 1.0 - bias
    e_max = bias
    maxval = exact_pow2(e_max) * (2.0 - exact_pow2(-m))
    e = jnp.clip(floor_log2(jnp.abs(x)), e_min, e_max)
    step = exact_pow2(jnp.clip(e - m, EXP_MIN, EXP_MAX))
    mag = jnp.round(x / step)
    return jnp.clip(mag * step, -maxval, maxval)


def select_quantize_ref(x: jax.Array, mode, bits) -> jax.Array:
    """mode: 0 = identity (fp32), 1 = dynamic fixed point, 2 = BFP,
    3 = fixed-sr (fixed grid, nearest), 4 = float, 5 = float-sr (float
    grid, nearest)."""
    mode = jnp.asarray(mode, jnp.float32)
    qf = fixed_quantize_ref(x, bits)
    qb = bfp_quantize_ref(x, bits)
    qe = float_quantize_ref(x, bits)
    fixed_like = jnp.logical_or(mode == 1.0, mode == 3.0)
    float_like = jnp.logical_or(mode == 4.0, mode == 5.0)
    return jnp.where(
        fixed_like, qf, jnp.where(mode == 2.0, qb, jnp.where(float_like, qe, x))
    )


def qgemm_ref(x: jax.Array, w: jax.Array, mode, bx, bw) -> jax.Array:
    """Quantize both operands, then matmul in f32 (wide accumulation).

    BFP boxes lie along the contraction axis for BOTH operands (x's last
    axis, w's first axis) — the MSFP hardware layout, so each dot product
    consumes whole boxes. w is therefore boxed through its transpose.
    """
    xq = select_quantize_ref(x, mode, bx)
    wq = select_quantize_ref(w.T, mode, bw).T
    return xq @ wq
