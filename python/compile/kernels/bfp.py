"""Pallas kernel: Block-Floating-Point (MSFP) fake quantization.

This is the paper's L1 compute hot-spot: every tensor DSQ touches (GEMM
inputs, the q1 stash, backward gradients) goes through this quantizer, so
it is written as a Pallas kernel that lowers into the same HLO module as
the L2 model.

Layout / TPU mapping (see DESIGN.md §Hardware-Adaptation):

* the tensor is viewed as ``(rows, cols)`` with ``cols % BOX == 0``; the
  bounding box (16 elements sharing an exponent) lies along the minor
  (lane) dimension, so on a real TPU the per-box ``max``/scale/round are
  plain VPU lane operations and the box never straddles a tile;
* the grid walks row-blocks; each grid step holds one ``(block_rows, cols)``
  tile in VMEM. ``block_rows`` is chosen so a tile stays well under VMEM
  (≈16 MiB) — see ``pick_block_rows``;
* the runtime mantissa width ``m`` arrives as a ``(1, 1)`` f32 operand
  broadcast to every grid step, which is what lets the L3 coordinator
  re-tune precision step-by-step without recompiling;
* ``interpret=True`` everywhere in this repo: the CPU PJRT plugin cannot
  execute Mosaic custom-calls, so the kernel is lowered through the
  interpreter into plain HLO (same numerics, CPU-executable).

Semantics are identical to ``ref.bfp_quantize_ref`` (the pure-jnp oracle);
pytest asserts bit-equality.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BOX, EXP_MAX, EXP_MIN, PASSTHROUGH_BITS, exact_pow2

# VMEM budget used to pick the row-block size: one f32 input tile + one
# output tile must fit with generous headroom (real TPU VMEM ≈ 16 MiB/core).
_VMEM_BUDGET_BYTES = 4 * 1024 * 1024


def pick_block_rows(rows: int, cols: int) -> int:
    """Largest row-block that (a) divides ``rows`` and (b) fits the budget."""
    per_row = cols * 4 * 2  # input + output f32 tiles
    cap = max(1, _VMEM_BUDGET_BYTES // per_row)
    best = 1
    for cand in range(1, min(rows, cap) + 1):
        if rows % cand == 0:
            best = cand
    return best


def _bfp_kernel(m_ref, x_ref, o_ref, *, box: int):
    """One row-block: per-box shared exponent -> round -> clamp -> dequant."""
    x = x_ref[...]
    m = m_ref[0, 0]
    br, cols = x.shape
    boxed = x.reshape(br, cols // box, box)
    amax = jnp.max(jnp.abs(boxed), axis=-1, keepdims=True)
    # floor(log2(amax)) via the IEEE-754 exponent field — exact, and
    # identical to the rust mirror (rust/src/quant/bfp.rs).
    ebits = jax.lax.bitcast_convert_type(amax, jnp.int32)
    e = (((ebits >> 23) & 0xFF) - 127).astype(jnp.float32)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    # exact_pow2 + clamp to normal range: XLA exp2 is inexact, and FTZ
    # would flush a subnormal step to zero (see ref._quantize_with_exponent).
    step = exact_pow2(jnp.clip(e - m + 2.0, EXP_MIN, EXP_MAX))
    maxmag = exact_pow2(m - 1.0) - 1.0
    mag = jnp.clip(jnp.round(boxed / step), -maxmag, maxmag)
    q = (mag * step).reshape(br, cols)
    q = jnp.where((amax > 0.0).reshape(br, cols // box, 1).repeat(box, -1).reshape(br, cols), q, 0.0)
    o_ref[...] = jnp.where(m >= PASSTHROUGH_BITS, x, q)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _bfp_quantize_2d(x: jax.Array, mbits: jax.Array, interpret: bool = True) -> jax.Array:
    """Pallas call over a padded 2D view; x.shape[1] % BOX == 0 required."""
    rows, cols = x.shape
    br = pick_block_rows(rows, cols)
    m2d = mbits.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_bfp_kernel, box=BOX),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(m2d, x)


def bfp_quantize(x: jax.Array, mbits, interpret: bool = True) -> jax.Array:
    """BFP fake-quantize an arbitrary-shape f32 tensor (boxes on last axis).

    Wrapper responsibilities: flatten leading axes, zero-pad the last axis
    to a BOX multiple (padding never changes a real box's max because a box
    is either all-real, all-pad, or real-prefix+zero-pad), call the kernel,
    slice back.
    """
    x = jnp.asarray(x, jnp.float32)
    m = jnp.asarray(mbits, jnp.float32)
    orig_shape = x.shape
    n = x.shape[-1] if x.ndim else 1
    flat = x.reshape(-1, n) if x.ndim else x.reshape(1, 1)
    inner = flat.shape[-1]
    pad = (-inner) % BOX
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    q = _bfp_quantize_2d(flat, m, interpret=interpret)
    if pad:
        q = q[:, :inner]
    return q.reshape(orig_shape)
