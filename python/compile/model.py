"""L2: the paper's models, built on the DSQ ops in layers.py.

Two model families, matching the paper's evaluation:

* :class:`Seq2SeqConfig` — a pre-LN encoder–decoder transformer
  (Vaswani et al.), the "6-layer transformer" used for IWSLT/WMT
  translation, here dimension-scaled to the testbed (DESIGN.md §4) —
  the *architecture* (pre-LN blocks, MHA, label-smoothed CE ε=0.1,
  Adam β=(0.9,0.98), tied output embedding) is kept;
* classifier (:class:`ClassifierConfig`) — an encoder + mean-pool + MLP
  head standing in for the RoBERTa-base GLUE fine-tuning runs.

All GEMMs (projections, attention, FFN, logits) run the DSQ custom-VJP
flow; LayerNorm / softmax / embedding-gather / loss stay f32 (paper §3
quantizes GEMMs and the fwd→bwd stash only).

Conventions: token 0 = PAD, 1 = BOS, 2 = EOS. Masks are derived in-graph
from the tokens, so artifacts take only token tensors as input.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import adam
from .layers import dsq_dot, dsq_linear, ffn, layer_norm, multi_head_attention

PAD, BOS, EOS = 0, 1, 2
NEG_INF = -1e9
LABEL_SMOOTHING = 0.1

FP32_QCFG = (0.0, 32.0, 0.0, 32.0, 0.0, 32.0, 0.0, 32.0)


@dataclass(frozen=True)
class Seq2SeqConfig:
    vocab: int = 256
    d_model: int = 128
    nheads: int = 4
    d_ff: int = 256
    enc_layers: int = 2
    dec_layers: int = 2
    src_len: int = 24
    tgt_len: int = 24
    batch: int = 16

    @property
    def head_dim(self) -> int:
        return self.d_model // self.nheads


@dataclass(frozen=True)
class ClassifierConfig:
    vocab: int = 256
    d_model: int = 128
    nheads: int = 4
    d_ff: int = 256
    layers: int = 2
    seq_len: int = 48
    nclasses: int = 3
    batch: int = 16


# ------------------------------------------------------------------ init


def _dense_init(key, fan_in, fan_out):
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


def _attn_params(keys, prefix: str, d: int) -> dict:
    p = {}
    for i, name in enumerate(("q", "k", "v", "o")):
        p[f"{prefix}.w{name}"] = _dense_init(keys[i], d, d)
        p[f"{prefix}.b{name}"] = jnp.zeros((d,), jnp.float32)
    return p


def _block_common(key, prefix: str, d: int, d_ff: int) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        f"{prefix}.ln1.g": jnp.ones((d,), jnp.float32),
        f"{prefix}.ln1.b": jnp.zeros((d,), jnp.float32),
        f"{prefix}.ln2.g": jnp.ones((d,), jnp.float32),
        f"{prefix}.ln2.b": jnp.zeros((d,), jnp.float32),
        f"{prefix}.ffn.w1": _dense_init(ks[0], d, d_ff),
        f"{prefix}.ffn.b1": jnp.zeros((d_ff,), jnp.float32),
        f"{prefix}.ffn.w2": _dense_init(ks[1], d_ff, d),
        f"{prefix}.ffn.b2": jnp.zeros((d,), jnp.float32),
    }
    p.update(_attn_params(jax.random.split(ks[2], 4), f"{prefix}.attn", d))
    return p


def init_seq2seq(cfg: Seq2SeqConfig, seed) -> dict:
    """Initialize all parameters from a (runtime) integer seed."""
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    n_blocks = cfg.enc_layers + cfg.dec_layers
    keys = jax.random.split(key, n_blocks + 4)
    p = {
        "src_emb": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "tgt_emb": jax.random.normal(keys[1], (cfg.vocab, cfg.d_model)) * 0.02,
        "src_pos": jax.random.normal(keys[2], (cfg.src_len, cfg.d_model)) * 0.02,
        "tgt_pos": jax.random.normal(keys[3], (cfg.tgt_len, cfg.d_model)) * 0.02,
        "enc_ln.g": jnp.ones((cfg.d_model,), jnp.float32),
        "enc_ln.b": jnp.zeros((cfg.d_model,), jnp.float32),
        "dec_ln.g": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_ln.b": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    for i in range(cfg.enc_layers):
        p.update(_block_common(keys[4 + i], f"enc{i}", cfg.d_model, cfg.d_ff))
    for i in range(cfg.dec_layers):
        k = keys[4 + cfg.enc_layers + i]
        p.update(_block_common(k, f"dec{i}", cfg.d_model, cfg.d_ff))
        kx = jax.random.split(jax.random.fold_in(k, 7), 4)
        p.update(_attn_params(kx, f"dec{i}.xattn", cfg.d_model))
        p[f"dec{i}.ln3.g"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"dec{i}.ln3.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def init_classifier(cfg: ClassifierConfig, seed) -> dict:
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    keys = jax.random.split(key, cfg.layers + 4)
    p = {
        "emb": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "pos": jax.random.normal(keys[1], (cfg.seq_len, cfg.d_model)) * 0.02,
        "enc_ln.g": jnp.ones((cfg.d_model,), jnp.float32),
        "enc_ln.b": jnp.zeros((cfg.d_model,), jnp.float32),
        "head.w1": _dense_init(keys[2], cfg.d_model, cfg.d_model),
        "head.b1": jnp.zeros((cfg.d_model,), jnp.float32),
        "head.w2": _dense_init(keys[3], cfg.d_model, cfg.nclasses),
        "head.b2": jnp.zeros((cfg.nclasses,), jnp.float32),
    }
    for i in range(cfg.layers):
        p.update(_block_common(keys[4 + i - cfg.layers], f"enc{i}", cfg.d_model, cfg.d_ff))
    return p


# -------------------------------------------------------- encoder/decoder


def _enc_block(x, p, prefix, nheads, mask, qcfg):
    h = layer_norm(x, p[f"{prefix}.ln1.g"], p[f"{prefix}.ln1.b"])
    x = x + multi_head_attention(h, h, p, f"{prefix}.attn", nheads, mask, qcfg)
    h = layer_norm(x, p[f"{prefix}.ln2.g"], p[f"{prefix}.ln2.b"])
    return x + ffn(h, p, f"{prefix}.ffn", qcfg)


def encode(p: dict, cfg: Seq2SeqConfig, src: jax.Array, qcfg: jax.Array) -> jax.Array:
    """src: (B, S) int32 -> (B, S, D) encoder states (final LN applied)."""
    pad_mask = jnp.where(src == PAD, NEG_INF, 0.0)[:, None, None, :]
    x = p["src_emb"][src] + p["src_pos"][None, :, :]
    for i in range(cfg.enc_layers):
        x = _enc_block(x, p, f"enc{i}", cfg.nheads, pad_mask, qcfg)
    return layer_norm(x, p["enc_ln.g"], p["enc_ln.b"])


def decode_states(
    p: dict,
    cfg: Seq2SeqConfig,
    enc: jax.Array,
    src: jax.Array,
    tgt_in: jax.Array,
    qcfg: jax.Array,
) -> jax.Array:
    """tgt_in: (B, T) int32 -> (B, T, V) logits (tied output embedding)."""
    T = cfg.tgt_len
    causal = jnp.where(jnp.tril(jnp.ones((T, T), bool)), 0.0, NEG_INF)[None, None, :, :]
    tgt_pad = jnp.where(tgt_in == PAD, NEG_INF, 0.0)[:, None, None, :]
    self_mask = causal + tgt_pad
    cross_mask = jnp.where(src == PAD, NEG_INF, 0.0)[:, None, None, :]
    x = p["tgt_emb"][tgt_in] + p["tgt_pos"][None, :, :]
    for i in range(cfg.dec_layers):
        h = layer_norm(x, p[f"dec{i}.ln1.g"], p[f"dec{i}.ln1.b"])
        x = x + multi_head_attention(h, h, p, f"dec{i}.attn", cfg.nheads, self_mask, qcfg)
        h = layer_norm(x, p[f"dec{i}.ln3.g"], p[f"dec{i}.ln3.b"])
        x = x + multi_head_attention(h, enc, p, f"dec{i}.xattn", cfg.nheads, cross_mask, qcfg)
        h = layer_norm(x, p[f"dec{i}.ln2.g"], p[f"dec{i}.ln2.b"])
        x = x + ffn(h, p, f"dec{i}.ffn", qcfg)
    x = layer_norm(x, p["dec_ln.g"], p["dec_ln.b"])
    # Tied output projection: logits = x @ tgt_embᵀ, as a DSQ GEMM.
    B = x.shape[0]
    logits = dsq_dot(x.reshape(B * T, -1), p["tgt_emb"].T, qcfg)
    return logits.reshape(B, T, cfg.vocab)


# ------------------------------------------------------------------ losses


def smoothed_ce(logits: jax.Array, targets: jax.Array, vocab: int):
    """Label-smoothed CE (ε=0.1), PAD-masked. Returns (loss_sum, ntok)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    conf = 1.0 - LABEL_SMOOTHING
    low = LABEL_SMOOTHING / (vocab - 1)
    onehot = jax.nn.one_hot(targets, vocab, dtype=jnp.float32)
    soft = onehot * conf + (1.0 - onehot) * low
    nll = -jnp.sum(soft * logp, axis=-1)
    mask = (targets != PAD).astype(jnp.float32)
    return jnp.sum(nll * mask), jnp.sum(mask)


def nmt_loss(p, cfg: Seq2SeqConfig, src, tgt_in, tgt_out, qcfg):
    enc = encode(p, cfg, src, qcfg)
    logits = decode_states(p, cfg, enc, src, tgt_in, qcfg)
    loss_sum, ntok = smoothed_ce(logits, tgt_out, cfg.vocab)
    return loss_sum / jnp.maximum(ntok, 1.0), (loss_sum, ntok, logits)


# ------------------------------------------------------------------- steps


def nmt_train_step(p, m, v, step, src, tgt_in, tgt_out, qcfg, lr, cfg: Seq2SeqConfig):
    """One full training step: DSQ fwd + bwd + Adam. Returns new state."""
    (loss, _aux), grads = jax.value_and_grad(
        lambda pp: nmt_loss(pp, cfg, src, tgt_in, tgt_out, qcfg), has_aux=True
    )(p)
    p2, m2, v2 = adam.update(p, grads, m, v, step, lr)
    return p2, m2, v2, loss


def nmt_eval_step(p, src, tgt_in, tgt_out, cfg: Seq2SeqConfig):
    """Teacher-forced eval in fp32: (loss_sum, ncorrect, ntok)."""
    qcfg = jnp.asarray(FP32_QCFG, jnp.float32)
    _, (loss_sum, ntok, logits) = nmt_loss(p, cfg, src, tgt_in, tgt_out, qcfg)
    pred = jnp.argmax(logits, axis=-1)
    mask = (tgt_out != PAD).astype(jnp.float32)
    ncorrect = jnp.sum((pred == tgt_out).astype(jnp.float32) * mask)
    return loss_sum, ncorrect, ntok


def nmt_greedy_decode(p, src, cfg: Seq2SeqConfig):
    """Greedy decode (fp32): (B, S) int32 -> (B, T) generated tokens."""
    qcfg = jnp.asarray(FP32_QCFG, jnp.float32)
    enc = encode(p, cfg, src, qcfg)
    B, T = src.shape[0], cfg.tgt_len

    def body(t, tgt):
        logits = decode_states(p, cfg, enc, src, tgt, qcfg)
        nxt = jnp.argmax(logits[:, t, :], axis=-1).astype(jnp.int32)
        return jax.lax.dynamic_update_slice(tgt, nxt[:, None], (0, t + 1))

    tgt0 = jnp.full((B, T), PAD, jnp.int32).at[:, 0].set(BOS)
    return jax.lax.fori_loop(0, T - 1, body, tgt0)


# --------------------------------------------------------------- classifier


def classifier_logits(p, cfg: ClassifierConfig, tokens, qcfg):
    pad_mask = jnp.where(tokens == PAD, NEG_INF, 0.0)[:, None, None, :]
    x = p["emb"][tokens] + p["pos"][None, :, :]
    for i in range(cfg.layers):
        x = _enc_block(x, p, f"enc{i}", cfg.nheads, pad_mask, qcfg)
    x = layer_norm(x, p["enc_ln.g"], p["enc_ln.b"])
    keep = (tokens != PAD).astype(jnp.float32)[:, :, None]
    pooled = jnp.sum(x * keep, axis=1) / jnp.maximum(jnp.sum(keep, axis=1), 1.0)
    h = jax.nn.relu(dsq_linear(pooled, p["head.w1"], p["head.b1"], qcfg))
    return dsq_linear(h, p["head.w2"], p["head.b2"], qcfg)


def cls_loss(p, cfg: ClassifierConfig, tokens, labels, qcfg):
    logits = classifier_logits(p, cfg, tokens, qcfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll), logits


def cls_train_step(p, m, v, step, tokens, labels, qcfg, lr, cfg: ClassifierConfig):
    (loss, _), grads = jax.value_and_grad(
        lambda pp: cls_loss(pp, cfg, tokens, labels, qcfg), has_aux=True
    )(p)
    p2, m2, v2 = adam.update(p, grads, m, v, step, lr)
    return p2, m2, v2, loss


def cls_eval_step(p, tokens, labels, cfg: ClassifierConfig):
    qcfg = jnp.asarray(FP32_QCFG, jnp.float32)
    loss, logits = cls_loss(p, cfg, tokens, labels, qcfg)
    ncorrect = jnp.sum((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    n = jnp.full((), float(labels.shape[0]), jnp.float32)
    return loss, ncorrect, n
