"""AOT pipeline: lower the L2 models to HLO **text** + a JSON manifest.

Run once by ``make artifacts`` (python never appears on the request
path). Each exported function is jitted, lowered to StableHLO, converted
to an XlaComputation and dumped as HLO *text* — jax ≥ 0.5 serialized
protos carry 64-bit instruction ids that the rust side's xla_extension
0.5.1 rejects, while the text parser reassigns ids (see
/opt/xla-example/README.md and DESIGN.md §2).

Flat calling convention: parameter dicts are flattened to tuples in
sorted-key order; ``manifest.json`` records the exact order and shapes so
the rust runtime can marshal literals positionally. All exported
functions return tuples (``return_tuple=True``), unwrapped on the rust
side via tuple decomposition.

Shapes are baked at lowering; precision (``qcfg`` — four per-slot
``[mode, bits]`` pairs, see layers.py) and learning rate stay runtime
inputs so the L3 dynamic controller never recompiles.

Config via environment (defaults = the "small" testbed preset):
  DSQ_VOCAB, DSQ_DMODEL, DSQ_HEADS, DSQ_DFF, DSQ_ENC_LAYERS,
  DSQ_DEC_LAYERS, DSQ_SRC_LEN, DSQ_TGT_LEN, DSQ_BATCH,
  DSQ_CLS_SEQ, DSQ_CLS_LAYERS, DSQ_CLS_CLASSES
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layers
from . import model as M
from .kernels.bfp import bfp_quantize
from .kernels.fixed import fixed_quantize
from .kernels.floatq import float_quantize

F32 = jnp.float32
I32 = jnp.int32


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def nmt_config() -> M.Seq2SeqConfig:
    return M.Seq2SeqConfig(
        vocab=_env_int("DSQ_VOCAB", 256),
        d_model=_env_int("DSQ_DMODEL", 128),
        nheads=_env_int("DSQ_HEADS", 4),
        d_ff=_env_int("DSQ_DFF", 256),
        enc_layers=_env_int("DSQ_ENC_LAYERS", 2),
        dec_layers=_env_int("DSQ_DEC_LAYERS", 2),
        src_len=_env_int("DSQ_SRC_LEN", 24),
        tgt_len=_env_int("DSQ_TGT_LEN", 24),
        batch=_env_int("DSQ_BATCH", 16),
    )


def cls_config() -> M.ClassifierConfig:
    return M.ClassifierConfig(
        vocab=_env_int("DSQ_VOCAB", 256),
        d_model=_env_int("DSQ_DMODEL", 128),
        nheads=_env_int("DSQ_HEADS", 4),
        d_ff=_env_int("DSQ_DFF", 256),
        layers=_env_int("DSQ_CLS_LAYERS", 2),
        seq_len=_env_int("DSQ_CLS_SEQ", 48),
        nclasses=_env_int("DSQ_CLS_CLASSES", 3),
        batch=_env_int("DSQ_BATCH", 16),
    )


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs(params: dict) -> list[tuple[str, tuple[int, ...]]]:
    return [(k, tuple(int(d) for d in params[k].shape)) for k in sorted(params)]


def _shape(s, dtype=F32):
    return jax.ShapeDtypeStruct(s, dtype)


def export(fn, example_args, path: str) -> int:
    # The per-quantizer train variants lower the SAME train_fn object
    # under different layers._QUANTIZERS settings; jax's global trace
    # cache keys on function identity and would silently reuse the
    # previous variant's trace, emitting byte-identical artifacts.
    jax.clear_caches()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


# ----------------------------------------------------------- flat wrappers


def build_nmt_exports(cfg: M.Seq2SeqConfig):
    """Return ({name: (fn, example_args)}, param_specs) for seq2seq."""
    p0 = jax.eval_shape(lambda s: M.init_seq2seq(cfg, s), jnp.zeros((), I32))
    names = sorted(p0.keys())
    shapes = [p0[k].shape for k in names]
    n = len(names)

    def pack(flat):
        return dict(zip(names, flat))

    def init_fn(seed):
        p = M.init_seq2seq(cfg, seed)
        return tuple(p[k] for k in names)

    def train_fn(*args):
        p = pack(args[0:n])
        m = pack(args[n : 2 * n])
        v = pack(args[2 * n : 3 * n])
        step, src, tgt_in, tgt_out, qcfg, lr = args[3 * n :]
        p2, m2, v2, loss = M.nmt_train_step(p, m, v, step, src, tgt_in, tgt_out, qcfg, lr, cfg)
        return (
            tuple(p2[k] for k in names)
            + tuple(m2[k] for k in names)
            + tuple(v2[k] for k in names)
            + (loss,)
        )

    def eval_fn(*args):
        p = pack(args[0:n])
        src, tgt_in, tgt_out = args[n:]
        return M.nmt_eval_step(p, src, tgt_in, tgt_out, cfg)

    def decode_fn(*args):
        p = pack(args[0:n])
        (src,) = args[n:]
        return (M.nmt_greedy_decode(p, src, cfg),)

    ps = [_shape(s) for s in shapes]
    B, S, T = cfg.batch, cfg.src_len, cfg.tgt_len
    scalar = _shape((), F32)
    qcfg = _shape((8,), F32)
    train_args = (
        ps * 3
        + [scalar, _shape((B, S), I32), _shape((B, T), I32), _shape((B, T), I32), qcfg, scalar]
    )
    exports = {
        "init": (init_fn, [_shape((), I32)]),
        # Per-quantizer train variants: identical signature, the variant
        # bakes which quantizer family its exact mode match selects
        # (compile-time split, see layers.set_quantizers); "train_both"
        # carries every quantizer subgraph for heterogeneous per-slot
        # configs — the rust coordinator routes cross-family configs
        # there (runtime/artifact.rs::train_variant_for).
        "train_bfp": (train_fn, train_args),
        "train_fixed": (train_fn, train_args),
        "train_float": (train_fn, train_args),
        "train_both": (train_fn, train_args),
        "eval": (eval_fn, ps + [_shape((B, S), I32), _shape((B, T), I32), _shape((B, T), I32)]),
        "decode": (decode_fn, ps + [_shape((B, S), I32)]),
    }
    return exports, param_specs(p0)


def build_cls_exports(cfg: M.ClassifierConfig):
    p0 = jax.eval_shape(lambda s: M.init_classifier(cfg, s), jnp.zeros((), I32))
    names = sorted(p0.keys())
    shapes = [p0[k].shape for k in names]
    n = len(names)

    def pack(flat):
        return dict(zip(names, flat))

    def init_fn(seed):
        p = M.init_classifier(cfg, seed)
        return tuple(p[k] for k in names)

    def train_fn(*args):
        p = pack(args[0:n])
        m = pack(args[n : 2 * n])
        v = pack(args[2 * n : 3 * n])
        step, tokens, labels, qcfg, lr = args[3 * n :]
        p2, m2, v2, loss = M.cls_train_step(p, m, v, step, tokens, labels, qcfg, lr, cfg)
        return (
            tuple(p2[k] for k in names)
            + tuple(m2[k] for k in names)
            + tuple(v2[k] for k in names)
            + (loss,)
        )

    def eval_fn(*args):
        p = pack(args[0:n])
        tokens, labels = args[n:]
        return M.cls_eval_step(p, tokens, labels, cfg)

    ps = [_shape(s) for s in shapes]
    B, L = cfg.batch, cfg.seq_len
    scalar = _shape((), F32)
    train_args = (
        ps * 3 + [scalar, _shape((B, L), I32), _shape((B,), I32), _shape((8,), F32), scalar]
    )
    exports = {
        "init": (init_fn, [_shape((), I32)]),
        "train_bfp": (train_fn, train_args),
        "train_fixed": (train_fn, train_args),
        "train_float": (train_fn, train_args),
        "train_both": (train_fn, train_args),
        "eval": (eval_fn, ps + [_shape((B, L), I32), _shape((B,), I32)]),
    }
    return exports, param_specs(p0)


QUANT_SHAPE = (64, 64)


def build_quant_exports():
    """Standalone quantizer artifacts — the rust mirrors cross-check
    against these (integration tests) and they double as runtime probes.

    The ``quant_select_*`` probes export ``layers.quantize`` itself under
    each per-variant compile (mode + bits as runtime inputs): they pin
    the variant dispatch contract — a single-family variant quantizes
    ONLY its exact modes and is the identity elsewhere (the artifact-side
    half of the cross-family dispatch bugfix; ``artifact_roundtrip.rs``
    asserts it end to end)."""

    def bfp_fn(x, bits):
        return (bfp_quantize(x, bits),)

    def fixed_fn(x, bits):
        return (fixed_quantize(x, bits),)

    def float_fn(x, code):
        return (float_quantize(x, code),)

    def select_fn(x, mode, bits):
        return (layers.quantize(x, mode, bits),)

    args = [_shape(QUANT_SHAPE), _shape((), F32)]
    sel_args = [_shape(QUANT_SHAPE), _shape((), F32), _shape((), F32)]
    return {
        "quant_bfp": (bfp_fn, args),
        "quant_fixed": (fixed_fn, args),
        "quant_float": (float_fn, args),
        "quant_select_bfp": (select_fn, sel_args),
        "quant_select_fixed": (select_fn, sel_args),
        "quant_select_float": (select_fn, sel_args),
        "quant_select_both": (select_fn, sel_args),
    }


# ------------------------------------------------------------------- main


def main() -> None:
    ap = argparse.ArgumentParser(description="DSQ AOT artifact builder")
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default="", help="comma-separated artifact subset (e.g. nmt_train,quant_bfp)"
    )
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    only = set(filter(None, args.only.split(",")))

    ncfg = nmt_config()
    ccfg = cls_config()
    nmt_exports, nmt_params = build_nmt_exports(ncfg)
    cls_exports, cls_params = build_cls_exports(ccfg)
    quant_exports = build_quant_exports()

    manifest = {
        "version": 1,
        "models": {
            "nmt": {
                "config": {
                    "vocab": ncfg.vocab,
                    "d_model": ncfg.d_model,
                    "nheads": ncfg.nheads,
                    "d_ff": ncfg.d_ff,
                    "enc_layers": ncfg.enc_layers,
                    "dec_layers": ncfg.dec_layers,
                    "src_len": ncfg.src_len,
                    "tgt_len": ncfg.tgt_len,
                    "batch": ncfg.batch,
                },
                "params": [{"name": k, "shape": list(s)} for k, s in nmt_params],
                "artifacts": {k: f"nmt_{k}.hlo.txt" for k in nmt_exports},
            },
            "cls": {
                "config": {
                    "vocab": ccfg.vocab,
                    "d_model": ccfg.d_model,
                    "nheads": ccfg.nheads,
                    "d_ff": ccfg.d_ff,
                    "layers": ccfg.layers,
                    "seq_len": ccfg.seq_len,
                    "nclasses": ccfg.nclasses,
                    "batch": ccfg.batch,
                },
                "params": [{"name": k, "shape": list(s)} for k, s in cls_params],
                "artifacts": {k: f"cls_{k}.hlo.txt" for k in cls_exports},
            },
        },
        "quant": {
            "shape": list(QUANT_SHAPE),
            "artifacts": {k: f"{k}.hlo.txt" for k in quant_exports},
        },
    }

    jobs = (
        [(f"nmt_{k}", fn, ex) for k, (fn, ex) in nmt_exports.items()]
        + [(f"cls_{k}", fn, ex) for k, (fn, ex) in cls_exports.items()]
        + [(k, fn, ex) for k, (fn, ex) in quant_exports.items()]
    )
    for name, fn, ex in jobs:
        if only and name not in only:
            continue
        # Train (and select-probe) variants bake a single quantizer path
        # (compile-time split).
        if name.endswith("_bfp"):
            layers.set_quantizers("bfp")
        elif name.endswith("_fixed"):
            layers.set_quantizers("fixed")
        elif name.endswith("_float"):
            layers.set_quantizers("float")
        else:
            layers.set_quantizers("both")
        path = os.path.join(outdir, f"{name}.hlo.txt")
        nbytes = export(fn, ex, path)
        print(f"  {name}: {nbytes} bytes -> {path}", file=sys.stderr)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"  manifest -> {outdir}/manifest.json", file=sys.stderr)


if __name__ == "__main__":
    main()
