"""L2 building blocks: the DSQ dataflow (paper Figure 2) as custom-VJP ops.

Every GEMM in the model goes through :func:`dsq_dot` (weights) or
:func:`dsq_bmm` (activation×activation, i.e. attention). The custom VJP
implements exactly the paper's four quantization points:

* ``q0`` — both forward-GEMM operands are quantized before the multiply;
* ``q1`` — the **stash**: the activations needed by the backward pass are
  quantized at ``q1`` *in the forward pass* and only that version is kept
  as a residual — the full-precision tensor is dead after the forward
  GEMM, which is the whole point (DRAM traffic between the passes drops
  to ``q1`` bits/element);
* ``q2`` — the incoming gradient and the weight are (re-)quantized at
  ``q2`` for the first backward GEMM (``dx = dy @ wᵀ``);
* ``q3`` — the outgoing gradient ``dx`` is quantized at ``q3`` before it
  is "written to DRAM" (returned), and the incoming ``dy`` is passed
  through the (idempotent) ``q3`` quantizer to model that it was fetched
  from DRAM in ``q3`` form. The weight-gradient GEMM therefore runs
  *numerically* on the q1 stash and the q3-form gradient; note the cost
  model deliberately *charges* that GEMM at ``q1 × q0`` — the only
  charging consistent with the paper's reported numbers (see the
  documented ambiguity in rust/src/costmodel/training.rs).

The precision vector ``qcfg = [m0,q0, m1,q1, m2,q2, m3,q3]`` is a
*runtime* f32 array of four per-slot ``[mode, bits]`` pairs (one per
quantization point q0..q3), mirroring the rust ``FormatSpec`` registry:
mode 0 = fp32 (identity), 1 = dynamic fixed point, 2 = BFP, 3 = fixed
point with stochastic rounding, 4 = low-bit float (``e<E>m<M>``: FP8
E4M3/E5M2, bf16, fp16 — the ``bits`` field packs both grid parameters
as ``100*E + M``), 5 = float with stochastic rounding. The stochastic
modes (3, 5) apply their family's grid with nearest rounding inside the
artifact — the stochastic stream exists host-side in the rust mirrors;
an artifact-side SR kernel is a ROADMAP open item. Per-slot modes make
heterogeneous configs (e.g. a BFP stash with fixed gradient outputs) a
runtime choice. Bits ≥ 25 short-circuit to identity for the integer
families, so fp32-style configs cost nothing numerically. BFP boxes
always lie along the contraction axis of the GEMM that consumes the
tensor (MSFP layout).

Master weights and the optimizer state stay f32 (the paper quantizes
GEMM operands and DRAM-resident intermediates, not the Adam state).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.bfp import bfp_quantize
from .kernels.fixed import fixed_quantize
from .kernels.floatq import float_quantize

# Pallas kernels are the default quantizer implementation (they lower into
# the AOT HLO); DSQ_NO_PALLAS=1 switches to the jnp oracle (bit-identical,
# used to A/B compile times and for fast python-side tests).
_USE_PALLAS = os.environ.get("DSQ_NO_PALLAS", "0") != "1"

# The qcfg mode table — the python half of the cross-language contract.
# These constants mirror ``FormatSpec::mode_scalar`` in
# rust/src/quant/format.rs one-for-one, and `dsq lint` (rule
# ``qcfg_sync``) diffs the two tables on every build, so skewing one
# side is a build failure instead of a silent wrong-kernel dispatch
# (the PR-4 bug class). All dispatch below goes through these names;
# raw ``mode == <number>`` literals are themselves a lint finding.
MODE_FP32 = 0.0
MODE_FIXED = 1.0
MODE_BFP = 2.0
MODE_FIXED_SR = 3.0
MODE_FLOAT = 4.0
MODE_FLOAT_SR = 5.0

MODES = {
    "fp32": MODE_FP32,
    "fixed": MODE_FIXED,
    "bfp": MODE_BFP,
    "fixedsr": MODE_FIXED_SR,
    "float": MODE_FLOAT,
    "floatsr": MODE_FLOAT_SR,
}

# Which quantizer paths are compiled into the graph. "both" supports the
# full runtime mode selector {0: fp32, 1: fixed, 2: bfp, 3: fixed-sr,
# 4: float, 5: float-sr}; "bfp" / "fixed" / "float" compile a single
# quantizer, cutting the number of quantize subgraphs — XLA 0.5.1's CPU
# pipeline scales badly with the subgraph count (~270 s vs ~100 s
# compile for the train step, DESIGN.md §Perf) — so aot.py exports
# per-quantizer *train* artifact variants (plus "train_both" for
# heterogeneous per-slot configs) and the rust coordinator picks by the
# slot families (runtime/artifact.rs::train_variant_for).
#
# Single-family variants apply their quantizer ONLY on an exact mode
# match and are the identity on every other mode. They used to dispatch
# `mode >= 1.0` into their own family, which silently quantized foreign
# slots with the wrong kernel (e.g. a fixed16sr grad slot run through
# the "bfp" variant came out BFP-quantized); the rust guard routes any
# cross-family config to train_both, and the exact match here makes a
# mis-routed config an obvious no-quantization instead of a silent
# wrong-grid one.
_QUANTIZERS = os.environ.get("DSQ_QUANTIZERS", "both")

_VARIANTS = ("both", "bfp", "fixed", "float")


def set_quantizers(which: str) -> None:
    """Select which quantizer paths future traces compile ("both"/"bfp"/
    "fixed"/"float"). Used by aot.py to emit per-variant train
    artifacts."""
    global _QUANTIZERS
    assert which in _VARIANTS, which
    _QUANTIZERS = which


def _bfp(x, bits):
    return bfp_quantize(x, bits) if _USE_PALLAS else ref.bfp_quantize_ref(x, bits)


def _fixed(x, bits):
    return fixed_quantize(x, bits) if _USE_PALLAS else ref.fixed_quantize_ref(x, bits)


def _float(x, bits):
    return float_quantize(x, bits) if _USE_PALLAS else ref.float_quantize_ref(x, bits)


def _fixed_like(mode):
    return jnp.logical_or(mode == MODE_FIXED, mode == MODE_FIXED_SR)


def _float_like(mode):
    return jnp.logical_or(mode == MODE_FLOAT, mode == MODE_FLOAT_SR)


def quantize(x: jax.Array, mode: jax.Array, bits: jax.Array) -> jax.Array:
    """Runtime-selected fake quantization; boxes along the last axis.

    The stochastic modes (3 fixed-sr, 5 float-sr) share their family's
    grid: inside the artifact they round to nearest (see the module
    docstring). Single-quantizer variants match their modes exactly and
    are the identity otherwise — never another family's kernel."""
    if _QUANTIZERS == "bfp":
        return jnp.where(mode == MODE_BFP, _bfp(x, bits), x)
    if _QUANTIZERS == "fixed":
        return jnp.where(_fixed_like(mode), _fixed(x, bits), x)
    if _QUANTIZERS == "float":
        return jnp.where(_float_like(mode), _float(x, bits), x)
    qf = _fixed(x, bits)
    qb = _bfp(x, bits)
    qe = _float(x, bits)
    return jnp.where(
        _fixed_like(mode),
        qf,
        jnp.where(mode == MODE_BFP, qb, jnp.where(_float_like(mode), qe, x)),
    )


def quantize_contract(x: jax.Array, mode: jax.Array, bits: jax.Array, axis: int) -> jax.Array:
    """Quantize with BFP boxes along ``axis`` (the contraction axis)."""
    if axis in (-1, x.ndim - 1):
        return quantize(x, mode, bits)
    xs = jnp.swapaxes(x, axis, -1)
    return jnp.swapaxes(quantize(xs, mode, bits), axis, -1)


# --------------------------------------------------------------- dsq_dot


@jax.custom_vjp
def dsq_dot(x: jax.Array, w: jax.Array, qcfg: jax.Array) -> jax.Array:
    """Quantized ``x @ w`` for a weight GEMM; x: (M, K), w: (K, N)."""
    m0, q0 = qcfg[0], qcfg[1]
    xq = quantize(x, m0, q0)  # boxes along K
    wq = quantize_contract(w, m0, q0, 0)  # boxes along K
    return xq @ wq


def _dsq_dot_fwd(x, w, qcfg):
    m0, q0, m1, q1 = qcfg[0], qcfg[1], qcfg[2], qcfg[3]
    xq = quantize(x, m0, q0)
    wq = quantize_contract(w, m0, q0, 0)
    y = xq @ wq
    # THE stash: x survives to the backward pass only in q1 form.
    xs = quantize(x, m1, q1)
    return y, (xs, w, qcfg)


def _dsq_dot_bwd(res, dy):
    xs, w, qcfg = res
    m2, q2, m3, q3 = qcfg[4], qcfg[5], qcfg[6], qcfg[7]
    # dy was written to DRAM at q3 by the consumer layer; model the fetch.
    dy = quantize(dy, m3, q3)
    # GEMM 2: dx = dy @ w^T, contraction over N -> boxes along N.
    dyq = quantize(dy, m2, q2)
    wq = quantize(w, m2, q2)  # boxes along N (w's last axis)
    dx = dyq @ wq.T
    dx = quantize(dx, m3, q3)  # written back to DRAM at q3
    # GEMM 3: dw = xs^T @ dy, runs on the q1 stash and the q3 gradient.
    dw = xs.T @ dy
    return dx, dw, jnp.zeros_like(qcfg)


dsq_dot.defvjp(_dsq_dot_fwd, _dsq_dot_bwd)


# --------------------------------------------------------------- dsq_bmm


@jax.custom_vjp
def dsq_bmm(a: jax.Array, b: jax.Array, qcfg: jax.Array) -> jax.Array:
    """Quantized batched ``a @ b`` (attention GEMMs).

    a: (..., M, K), b: (..., K, N), identical leading dims. Both operands
    are activations, so BOTH are stashed at q1 for the backward pass.
    """
    m0, q0 = qcfg[0], qcfg[1]
    aq = quantize(a, m0, q0)
    bq = quantize_contract(b, m0, q0, b.ndim - 2)
    return aq @ bq


def _dsq_bmm_fwd(a, b, qcfg):
    m0, q0, m1, q1 = qcfg[0], qcfg[1], qcfg[2], qcfg[3]
    aq = quantize(a, m0, q0)
    bq = quantize_contract(b, m0, q0, b.ndim - 2)
    y = aq @ bq
    a_s = quantize(a, m1, q1)
    b_s = quantize_contract(b, m1, q1, b.ndim - 2)
    return y, (a_s, b_s, qcfg)


def _dsq_bmm_bwd(res, dy):
    a_s, b_s, qcfg = res
    m2, q2, m3, q3 = qcfg[4], qcfg[5], qcfg[6], qcfg[7]
    dy = quantize(dy, m3, q3)
    dyq = quantize(dy, m2, q2)
    # da = dy @ b^T (contraction over N): b_s is the q1 DRAM copy.
    da = dyq @ jnp.swapaxes(b_s, -1, -2)
    da = quantize(da, m3, q3)
    # db = a^T @ dy (contraction over M).
    db = jnp.swapaxes(a_s, -1, -2) @ dy
    db = quantize_contract(db, m3, q3, db.ndim - 2)
    return da, db, jnp.zeros_like(qcfg)


dsq_bmm.defvjp(_dsq_bmm_fwd, _dsq_bmm_bwd)


# --------------------------------------------------------------- layers


def dsq_linear(x: jax.Array, w: jax.Array, b: jax.Array, qcfg: jax.Array) -> jax.Array:
    """DSQ linear layer over the last axis of x (leading axes flattened)."""
    lead = x.shape[:-1]
    y = dsq_dot(x.reshape(-1, x.shape[-1]), w, qcfg)
    return y.reshape(*lead, w.shape[-1]) + b


def layer_norm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    """f32 LayerNorm (normalization ops are not quantized — paper §3)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def multi_head_attention(
    q_in: jax.Array,
    kv_in: jax.Array,
    p: dict,
    prefix: str,
    nheads: int,
    mask: jax.Array,
    qcfg: jax.Array,
) -> jax.Array:
    """DSQ multi-head attention; all four projections + both attention
    GEMMs (QKᵀ and AV) run through the DSQ flow.

    mask: additive (broadcastable to (B, H, Tq, Tk)), 0 = keep, -inf = drop.
    """
    B, Tq, D = q_in.shape
    Tk = kv_in.shape[1]
    dh = D // nheads
    q = dsq_linear(q_in, p[f"{prefix}.wq"], p[f"{prefix}.bq"], qcfg)
    k = dsq_linear(kv_in, p[f"{prefix}.wk"], p[f"{prefix}.bk"], qcfg)
    v = dsq_linear(kv_in, p[f"{prefix}.wv"], p[f"{prefix}.bv"], qcfg)
    q = q.reshape(B, Tq, nheads, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, Tk, nheads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, Tk, nheads, dh).transpose(0, 2, 1, 3)
    scores = dsq_bmm(q, jnp.swapaxes(k, -1, -2), qcfg) / jnp.sqrt(float(dh))
    scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1)  # f32 softmax
    ctx = dsq_bmm(probs, v, qcfg)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B, Tq, D)
    return dsq_linear(ctx, p[f"{prefix}.wo"], p[f"{prefix}.bo"], qcfg)


def ffn(x: jax.Array, p: dict, prefix: str, qcfg: jax.Array) -> jax.Array:
    h = jax.nn.relu(dsq_linear(x, p[f"{prefix}.w1"], p[f"{prefix}.b1"], qcfg))
    return dsq_linear(h, p[f"{prefix}.w2"], p[f"{prefix}.b2"], qcfg)
