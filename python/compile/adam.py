"""Adam optimizer (β₁=0.9, β₂=0.98, paper Appendix B) over a flat param dict.

State (m, v) and master weights are f32 — the paper quantizes GEMM
operands and the fwd→bwd stash, not the optimizer state. The learning
rate arrives as a runtime scalar: the LR *schedule* (inverse-sqrt /
polynomial decay) is owned by the rust coordinator (L3), keeping the AOT
graph schedule-agnostic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.98
EPS = 1e-9


def init_state(params: dict) -> tuple[dict, dict]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def update(
    params: dict,
    grads: dict,
    m: dict,
    v: dict,
    step: jax.Array,
    lr: jax.Array,
    weight_decay: float = 0.0,
) -> tuple[dict, dict, dict]:
    """One Adam step. ``step`` is the 1-based step count (f32 scalar)."""
    b1t = jnp.power(BETA1, step)
    b2t = jnp.power(BETA2, step)

    def upd(p, g, mi, vi):
        if weight_decay:
            g = g + weight_decay * p
        mn = BETA1 * mi + (1.0 - BETA1) * g
        vn = BETA2 * vi + (1.0 - BETA2) * jnp.square(g)
        mhat = mn / (1.0 - b1t)
        vhat = vn / (1.0 - b2t)
        pn = p - lr * mhat / (jnp.sqrt(vhat) + EPS)
        return pn, mn, vn

    out = jax.tree_util.tree_map(upd, params, grads, m, v)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_m, new_v
