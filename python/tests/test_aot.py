"""AOT pipeline tests: manifest consistency + HLO-text export sanity.

Exports use a tiny config (env overrides) into a tmpdir so the suite
doesn't depend on or touch the real ``artifacts/`` directory.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M

TINY_ENV = {
    "DSQ_VOCAB": "64",
    "DSQ_DMODEL": "32",
    "DSQ_HEADS": "2",
    "DSQ_DFF": "64",
    "DSQ_ENC_LAYERS": "1",
    "DSQ_DEC_LAYERS": "1",
    "DSQ_SRC_LEN": "16",
    "DSQ_TGT_LEN": "16",
    "DSQ_BATCH": "4",
    "DSQ_CLS_SEQ": "16",
    "DSQ_CLS_LAYERS": "1",
}


def test_param_specs_sorted_and_complete():
    cfg = M.Seq2SeqConfig(vocab=64, d_model=32, nheads=2, d_ff=64, enc_layers=1,
                          dec_layers=1, src_len=16, tgt_len=16, batch=4)
    p = M.init_seq2seq(cfg, 0)
    specs = aot.param_specs(p)
    names = [s[0] for s in specs]
    assert names == sorted(names)
    assert set(names) == set(p.keys())
    for name, shape in specs:
        assert tuple(p[name].shape) == shape


def test_nmt_exports_shapes():
    cfg = M.Seq2SeqConfig(vocab=64, d_model=32, nheads=2, d_ff=64, enc_layers=1,
                          dec_layers=1, src_len=16, tgt_len=16, batch=4)
    exports, specs = aot.build_nmt_exports(cfg)
    assert set(exports) == {
        "init", "train_bfp", "train_fixed", "train_float", "train_both", "eval", "decode",
    }
    n = len(specs)
    fn, ex = exports["train_bfp"]
    # params*3 + step + src + tgt_in + tgt_out + qcfg + lr
    assert len(ex) == 3 * n + 6
    out = jax.eval_shape(fn, *ex)
    assert len(out) == 3 * n + 1  # new p/m/v + loss
    for i, (_, shape) in enumerate(specs):
        assert tuple(out[i].shape) == shape


def test_hlo_text_export(tmp_path):
    def f(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    path = str(tmp_path / "f.hlo.txt")
    nbytes = aot.export(f, [spec, spec], path)
    text = open(path).read()
    assert nbytes == len(text) > 0
    assert "ENTRY" in text  # HLO text, not proto bytes
    assert "f32[4,4]" in text


def test_aot_main_writes_manifest(tmp_path):
    env = dict(os.environ, **TINY_ENV)
    out = str(tmp_path / "arts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", out, "--only", "quant_bfp"],
        check=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    man = json.load(open(os.path.join(out, "manifest.json")))
    assert man["version"] == 1
    assert man["models"]["nmt"]["config"]["d_model"] == 32
    names = [p["name"] for p in man["models"]["nmt"]["params"]]
    assert names == sorted(names)
    assert os.path.exists(os.path.join(out, "quant_bfp.hlo.txt"))
    # The float + select-dispatch probes are registered in the manifest
    # even when not exported in this --only run.
    quant = man["quant"]["artifacts"]
    for probe in ("quant_float", "quant_select_bfp", "quant_select_fixed",
                  "quant_select_float", "quant_select_both"):
        assert probe in quant, probe
    assert "train_float" in man["models"]["nmt"]["artifacts"]
    assert "train_float" in man["models"]["cls"]["artifacts"]


@pytest.mark.slow
def test_exported_train_step_runs_under_jax(tmp_path):
    """Full pallas-path train artifact executes and returns finite loss."""
    cfg = M.Seq2SeqConfig(vocab=64, d_model=32, nheads=2, d_ff=64, enc_layers=1,
                          dec_layers=1, src_len=16, tgt_len=16, batch=4)
    exports, specs = aot.build_nmt_exports(cfg)
    init_fn, _ = exports["init"]
    train_fn, ex = exports["train_bfp"]
    flat = init_fn(jnp.zeros((), jnp.int32))
    n = len(specs)
    zeros = tuple(jnp.zeros_like(t) for t in flat)
    rng = np.random.default_rng(0)
    src = rng.integers(3, 64, (4, 16)).astype(np.int32)
    tgt_in = np.concatenate([np.ones((4, 1), np.int32), src[:, :-1]], 1)
    qcfg = jnp.array([2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 16.0], jnp.float32)
    out = jax.jit(train_fn)(
        *flat, *zeros, *zeros, jnp.float32(1.0), src, tgt_in, src, qcfg, jnp.float32(1e-3)
    )
    loss = float(out[-1])
    assert np.isfinite(loss) and loss > 0
    # params moved
    assert not np.array_equal(np.asarray(out[0]), np.asarray(flat[0]))
