"""L2 DSQ-flow correctness: the custom VJP implements paper Figure 2.

Verifies, against hand-computed compositions of the ref quantizers, that
each of the four quantization points (q0 fwd GEMM, q1 stash, q2 first
backward GEMM, q3 gradient output) is applied exactly where the paper
puts it.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import layers
from compile.kernels import ref
from compile.layers import dsq_bmm, dsq_dot, quantize, quantize_contract

RNG = np.random.default_rng(7)


def rand(shape, lo=-3, hi=3):
    return (RNG.standard_normal(shape) * np.exp(RNG.uniform(lo, hi, shape))).astype(np.float32)


def qcfg(mode, q0, q1, q2, q3):
    """Uniform-mode config: four [mode, bits] slot pairs."""
    return jnp.array([mode, q0, mode, q1, mode, q2, mode, q3], jnp.float32)


def qcfg_slots(*slots):
    """Heterogeneous config from four (mode, bits) pairs."""
    flat = [v for pair in slots for v in pair]
    return jnp.array(flat, jnp.float32)


FP32 = qcfg(0, 32, 32, 32, 32)


# ------------------------------------------------------------- forward


def test_dot_fp32_is_plain_matmul():
    x, w = rand((8, 32)), rand((32, 16))
    got = np.asarray(dsq_dot(x, w, FP32))
    # XLA vs numpy accumulation order -> small relative noise is expected.
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


def test_dot_fwd_quantizes_at_q0():
    x, w = rand((8, 32)), rand((32, 16))
    c = qcfg(2, 4, 2, 8, 16)
    got = np.asarray(dsq_dot(x, w, c))
    xq = ref.bfp_quantize_ref(x, 4.0)
    wq = ref.bfp_quantize_ref(w.T, 4.0).T  # boxes along K
    np.testing.assert_allclose(got, np.asarray(xq @ wq), rtol=1e-6, atol=1e-6)


def test_dot_fixed_mode():
    x, w = rand((4, 16)), rand((16, 8))
    c = qcfg(1, 8, 8, 8, 16)
    got = np.asarray(dsq_dot(x, w, c))
    xq = ref.fixed_quantize_ref(x, 8.0)
    wq = ref.fixed_quantize_ref(w.T, 8.0).T
    np.testing.assert_allclose(got, np.asarray(xq @ wq), rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------- backward


def _dot_grads(x, w, c, gscale=1.0):
    def f(x, w):
        return jnp.sum(dsq_dot(x, w, c) * gscale)

    return jax.grad(f, argnums=(0, 1))(x, w)


def test_dot_fp32_grads_match_plain():
    x, w = rand((8, 32)), rand((32, 16))
    dx, dw = _dot_grads(x, w, FP32)
    dy = np.ones((8, 16), np.float32)
    np.testing.assert_allclose(np.asarray(dx), dy @ w.T, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), x.T @ dy, rtol=1e-5)


def test_dot_backward_quantization_points():
    """dx must equal q3(q2(dy) @ q2(w)ᵀ); dw must equal q1(x)ᵀ @ q3(dy)."""
    x, w = rand((8, 32)), rand((32, 16))
    q0, q1, q2, q3 = 16.0, 4.0, 4.0, 16.0
    c = qcfg(2, q0, q1, q2, q3)
    # Loss = sum(y * r) gives dy = r, a non-trivial upstream gradient.
    r = rand((8, 16), -1, 1)

    def f(x, w):
        return jnp.sum(dsq_dot(x, w, c) * r)

    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)

    dy = ref.bfp_quantize_ref(r, q3)  # fetched from DRAM at q3
    dyq = ref.bfp_quantize_ref(dy, q2)
    wq = ref.bfp_quantize_ref(w, q2)  # boxes along N
    dx_want = ref.bfp_quantize_ref(dyq @ wq.T, q3)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_want), rtol=1e-6, atol=1e-6)

    xs = ref.bfp_quantize_ref(x, q1)  # the stash
    dw_want = xs.T @ dy
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_want), rtol=1e-6, atol=1e-6)


def test_dot_stash_is_aggressive():
    """q1 ≪ q0: the weight gradient must be computed from the LOW-precision
    stash even though the forward pass used high precision."""
    x, w = rand((16, 32)), rand((32, 16))
    c_hi_stash = qcfg(2, 16, 16, 16, 16)
    c_lo_stash = qcfg(2, 16, 2, 16, 16)
    _, dw_hi = _dot_grads(x, w, c_hi_stash)
    _, dw_lo = _dot_grads(x, w, c_lo_stash)
    # Different stashes -> different dw; fwd outputs identical.
    y_hi = np.asarray(dsq_dot(x, w, c_hi_stash))
    y_lo = np.asarray(dsq_dot(x, w, c_lo_stash))
    np.testing.assert_allclose(y_hi, y_lo, rtol=1e-6)
    assert not np.allclose(np.asarray(dw_hi), np.asarray(dw_lo))


def test_dot_qcfg_gets_zero_grad():
    x, w = rand((4, 16)), rand((16, 8))
    c = qcfg(2, 8, 4, 4, 16)
    g = jax.grad(lambda cc: jnp.sum(dsq_dot(x, w, cc)))(c)
    np.testing.assert_array_equal(np.asarray(g), np.zeros(8, np.float32))


def test_dot_grad_error_grows_as_stash_shrinks():
    x, w = rand((32, 64), -1, 1), rand((64, 32), -1, 1)
    r = rand((32, 32), -1, 1)

    def dw_at(q1bits):
        c = qcfg(2, 25, q1bits, 25, 25)
        return np.asarray(jax.grad(lambda ww: jnp.sum(dsq_dot(x, ww, c) * r))(w))

    exact = x.T @ np.asarray(ref.bfp_quantize_ref(r, 25.0))
    errs = [np.abs(dw_at(b) - exact).mean() for b in (16.0, 8.0, 4.0, 2.0)]
    assert errs[0] <= errs[1] <= errs[2] <= errs[3]
    assert errs[3] > errs[0]


# ------------------------------------------------------------- dsq_bmm


def test_bmm_fp32_matches_plain():
    a, b = rand((2, 3, 8, 16)), rand((2, 3, 16, 8))
    got = np.asarray(dsq_bmm(a, b, FP32))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


def test_bmm_fwd_quantizes_both_operands():
    a, b = rand((2, 4, 16)), rand((2, 16, 8))
    c = qcfg(2, 4, 2, 8, 16)
    got = np.asarray(dsq_bmm(a, b, c))
    aq = np.asarray(ref.bfp_quantize_ref(a, 4.0))
    bq = np.asarray(quantize_contract(jnp.asarray(b), jnp.float32(2.0), jnp.float32(4.0), 1))
    np.testing.assert_allclose(got, aq @ bq, rtol=1e-6, atol=1e-6)


def test_bmm_backward_points():
    a, b = rand((2, 8, 16)), rand((2, 16, 8))
    q0, q1, q2, q3 = 16.0, 4.0, 4.0, 16.0
    c = qcfg(2, q0, q1, q2, q3)
    r = rand((2, 8, 8), -1, 1)
    da, db = jax.grad(lambda aa, bb: jnp.sum(dsq_bmm(aa, bb, c) * r), argnums=(0, 1))(a, b)

    dy = ref.bfp_quantize_ref(r, q3)
    dyq = ref.bfp_quantize_ref(dy, q2)
    b_s = np.asarray(quantize_contract(jnp.asarray(b), jnp.float32(2.0), jnp.float32(q1), 1))
    da_want = ref.bfp_quantize_ref(dyq @ np.swapaxes(b_s, -1, -2), q3)
    np.testing.assert_allclose(np.asarray(da), np.asarray(da_want), rtol=1e-6, atol=1e-6)

    a_s = ref.bfp_quantize_ref(a, q1)
    db_raw = jnp.swapaxes(jnp.asarray(a_s), -1, -2) @ dy
    db_want = quantize_contract(db_raw, jnp.float32(2.0), jnp.float32(q3), db_raw.ndim - 2)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_want), rtol=1e-6, atol=1e-6)


def test_dot_heterogeneous_slot_modes():
    """Per-slot modes: a BFP forward path with a fixed-point stash must
    quantize each point with its own family."""
    x, w = rand((8, 32)), rand((32, 16))
    c = qcfg_slots((2, 16), (1, 4), (2, 4), (2, 16))  # bfp16,fixed4,bfp4,bfp16
    r = rand((8, 16), -1, 1)

    def f(x, w):
        return jnp.sum(dsq_dot(x, w, c) * r)

    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
    # dw runs on the FIXED-quantized stash (slot 1, mode 1).
    dy = ref.bfp_quantize_ref(r, 16.0)
    xs = ref.fixed_quantize_ref(x, 4.0)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(xs.T @ dy), rtol=1e-6, atol=1e-6)
    # dx path stays BFP (slots 2/3, mode 2).
    dyq = ref.bfp_quantize_ref(ref.bfp_quantize_ref(r, 16.0), 4.0)
    wq = ref.bfp_quantize_ref(w, 4.0)
    dx_want = ref.bfp_quantize_ref(dyq @ wq.T, 16.0)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_want), rtol=1e-6, atol=1e-6)


def test_mode3_fixed_sr_uses_fixed_grid_in_graph():
    """Inside the artifact, mode 3 (fixed-sr) applies the fixed grid with
    nearest rounding (the stochastic stream is host-side only)."""
    x, w = rand((4, 16)), rand((16, 8))
    got = np.asarray(dsq_dot(x, w, qcfg(3, 8, 8, 8, 16)))
    want = np.asarray(dsq_dot(x, w, qcfg(1, 8, 8, 8, 16)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mode", [0.0, 1.0, 2.0, 3.0])
def test_bmm_modes_finite(mode):
    a, b = rand((2, 8, 16)), rand((2, 16, 8))
    c = qcfg(mode, 8, 4, 4, 16)
    y = np.asarray(dsq_bmm(a, b, c))
    assert np.isfinite(y).all()


# ------------------------------------------------------- float (mode 4/5)

E4M3 = ref.float_code(4, 3)
E5M2 = ref.float_code(5, 2)


def test_dot_float_mode():
    """Mode 4 runs the e<E>m<M> float grid at each quantization point."""
    x, w = rand((4, 16)), rand((16, 8))
    c = qcfg(4, E4M3, E4M3, E4M3, E5M2)
    got = np.asarray(dsq_dot(x, w, c))
    xq = ref.float_quantize_ref(x, E4M3)
    wq = ref.float_quantize_ref(w, E4M3)  # per-element: no box axis
    np.testing.assert_allclose(got, np.asarray(xq @ wq), rtol=1e-6, atol=1e-6)


def test_dot_float_backward_points():
    """FP8-LM slot assignment: E4M3 stash, E5M2 gradient traffic."""
    x, w = rand((8, 32), -2, 2), rand((32, 16), -2, 2)
    c = qcfg_slots((4, E4M3), (4, E4M3), (4, E4M3), (4, E5M2))
    r = rand((8, 16), -1, 1)

    def f(x, w):
        return jnp.sum(dsq_dot(x, w, c) * r)

    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
    dy = ref.float_quantize_ref(r, E5M2)  # fetched from DRAM at q3
    dyq = ref.float_quantize_ref(dy, E4M3)
    wq = ref.float_quantize_ref(w, E4M3)
    dx_want = ref.float_quantize_ref(dyq @ wq.T, E5M2)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_want), rtol=1e-6, atol=1e-6)
    xs = ref.float_quantize_ref(x, E4M3)  # the stash
    np.testing.assert_allclose(np.asarray(dw), np.asarray(xs.T @ dy), rtol=1e-6, atol=1e-6)


def test_mode5_float_sr_uses_float_grid_in_graph():
    """Inside the artifact, mode 5 (float-sr) applies the float grid with
    nearest rounding (the stochastic stream is host-side only)."""
    x, w = rand((4, 16)), rand((16, 8))
    got = np.asarray(dsq_dot(x, w, qcfg(5, E4M3, E4M3, E4M3, E5M2)))
    want = np.asarray(dsq_dot(x, w, qcfg(4, E4M3, E4M3, E4M3, E5M2)))
    np.testing.assert_array_equal(got, want)


def test_float_heterogeneous_with_integer_families():
    """A float fwd path with a BFP stash: each slot keeps its own family."""
    x, w = rand((8, 32)), rand((32, 16))
    c = qcfg_slots((4, E4M3), (2, 4), (4, E4M3), (4, E5M2))
    r = rand((8, 16), -1, 1)
    dx, dw = jax.grad(lambda x, w: jnp.sum(dsq_dot(x, w, c) * r), argnums=(0, 1))(x, w)
    dy = ref.float_quantize_ref(r, E5M2)
    xs = ref.bfp_quantize_ref(x, 4.0)  # slot 1 is bfp4
    np.testing.assert_allclose(np.asarray(dw), np.asarray(xs.T @ dy), rtol=1e-6, atol=1e-6)


# ------------------------------------- single-family variant dispatch

@pytest.fixture
def restore_quantizers():
    yield
    layers.set_quantizers("both")


def test_single_family_variants_match_modes_exactly(restore_quantizers):
    """The dispatch bugfix: a single-quantizer variant applies its kernel
    only on an exact mode match and is the identity otherwise. The old
    `mode >= 1` dispatch quantized foreign slots with the wrong kernel
    (e.g. a fixed16sr slot through the "bfp" variant came out BFP)."""
    x = jnp.asarray(rand((4, 32)))
    bits = jnp.float32(8.0)

    layers.set_quantizers("bfp")
    np.testing.assert_array_equal(
        np.asarray(quantize(x, jnp.float32(2.0), bits)),
        np.asarray(ref.bfp_quantize_ref(x, bits)),
    )
    # The regression: fixed/fixed-sr/float modes must NOT bfp-quantize.
    for mode in (1.0, 3.0, 4.0, 5.0):
        np.testing.assert_array_equal(
            np.asarray(quantize(x, jnp.float32(mode), bits)), np.asarray(x), err_msg=f"mode {mode}"
        )

    layers.set_quantizers("fixed")
    for mode in (1.0, 3.0):
        np.testing.assert_array_equal(
            np.asarray(quantize(x, jnp.float32(mode), bits)),
            np.asarray(ref.fixed_quantize_ref(x, bits)),
        )
    for mode in (0.0, 2.0, 4.0):
        np.testing.assert_array_equal(
            np.asarray(quantize(x, jnp.float32(mode), bits)), np.asarray(x), err_msg=f"mode {mode}"
        )

    layers.set_quantizers("float")
    for mode in (4.0, 5.0):
        np.testing.assert_array_equal(
            np.asarray(quantize(x, jnp.float32(mode), jnp.float32(E4M3))),
            np.asarray(ref.float_quantize_ref(x, E4M3)),
        )
    for mode in (1.0, 2.0, 3.0):
        np.testing.assert_array_equal(
            np.asarray(quantize(x, jnp.float32(mode), bits)), np.asarray(x), err_msg=f"mode {mode}"
        )


def test_both_variant_dispatches_every_family(restore_quantizers):
    layers.set_quantizers("both")
    x = jnp.asarray(rand((4, 32)))
    cases = [
        (0.0, 32.0, np.asarray(x)),
        (1.0, 8.0, np.asarray(ref.fixed_quantize_ref(x, 8.0))),
        (2.0, 8.0, np.asarray(ref.bfp_quantize_ref(x, 8.0))),
        (3.0, 8.0, np.asarray(ref.fixed_quantize_ref(x, 8.0))),
        (4.0, E4M3, np.asarray(ref.float_quantize_ref(x, E4M3))),
        (5.0, E5M2, np.asarray(ref.float_quantize_ref(x, E5M2))),
    ]
    for mode, bits, want in cases:
        got = np.asarray(quantize(x, jnp.float32(mode), jnp.float32(bits)))
        np.testing.assert_array_equal(got, want, err_msg=f"mode {mode}")


def test_mode_table_drives_dispatch(restore_quantizers):
    """The MODE_* / MODES table in layers.py is the runtime dispatch
    contract (`dsq lint` diffs it against FormatSpec::mode_scalar): each
    named family's scalar must route to that family's kernel, and a
    scalar outside the table must be the identity."""
    layers.set_quantizers("both")
    x = jnp.asarray(rand((4, 32)))
    bits = {"fp32": 32.0, "fixed": 8.0, "bfp": 8.0, "fixedsr": 8.0,
            "float": E4M3, "floatsr": E5M2}
    want = {
        "fp32": lambda b: np.asarray(x),
        "fixed": lambda b: np.asarray(ref.fixed_quantize_ref(x, b)),
        "fixedsr": lambda b: np.asarray(ref.fixed_quantize_ref(x, b)),
        "bfp": lambda b: np.asarray(ref.bfp_quantize_ref(x, b)),
        "float": lambda b: np.asarray(ref.float_quantize_ref(x, b)),
        "floatsr": lambda b: np.asarray(ref.float_quantize_ref(x, b)),
    }
    assert set(layers.MODES) == set(want), "MODES families drifted from this test"
    for family, mode in layers.MODES.items():
        got = np.asarray(quantize(x, jnp.float32(mode), jnp.float32(bits[family])))
        np.testing.assert_array_equal(got, want[family](bits[family]), err_msg=family)
    # Scalars outside the table: identity, never a foreign kernel.
    for mode in (-1.0, 2.5, 7.0):
        assert mode not in layers.MODES.values()
        np.testing.assert_array_equal(
            np.asarray(quantize(x, jnp.float32(mode), jnp.float32(8.0))),
            np.asarray(x),
            err_msg=f"mode {mode}",
        )
