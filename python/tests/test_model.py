"""L2 model tests: shapes, trainability under DSQ, eval/decode, classifier.

Uses a tiny config + the jnp quantizer path (DSQ_NO_PALLAS) for speed;
test_kernels.py already proves the pallas kernels are bit-identical, and
test_aot.py exercises the pallas path end-to-end.
"""

import os

os.environ.setdefault("DSQ_NO_PALLAS", "1")

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M

TINY = M.Seq2SeqConfig(
    vocab=64, d_model=32, nheads=2, d_ff=64, enc_layers=1, dec_layers=1,
    src_len=16, tgt_len=16, batch=8,
)
CTINY = M.ClassifierConfig(
    vocab=64, d_model=32, nheads=2, d_ff=64, layers=1, seq_len=16, nclasses=3, batch=8,
)

FP32 = jnp.asarray(M.FP32_QCFG, jnp.float32)
DSQ_AGGR = jnp.array([2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 16.0], jnp.float32)


def make_batch(cfg, rng):
    """Copy-task batch: target = source (learnable by a tiny model)."""
    lens = rng.integers(cfg.src_len // 2, cfg.src_len, cfg.batch)
    src = np.zeros((cfg.batch, cfg.src_len), np.int32)
    for i, L in enumerate(lens):
        src[i, :L] = rng.integers(3, cfg.vocab, L)
    tgt_in = np.concatenate([np.full((cfg.batch, 1), M.BOS, np.int32), src[:, :-1]], 1)
    return src, tgt_in, src.copy()


@pytest.fixture(scope="module")
def params():
    return M.init_seq2seq(TINY, 0)


def test_init_param_shapes(params):
    assert params["src_emb"].shape == (64, 32)
    assert params["enc0.attn.wq"].shape == (32, 32)
    assert params["dec0.xattn.wo"].shape == (32, 32)
    assert params["dec0.ffn.w1"].shape == (32, 64)
    for k, v in params.items():
        assert v.dtype == jnp.float32, k
        assert np.isfinite(np.asarray(v)).all(), k


def test_init_deterministic():
    p1 = M.init_seq2seq(TINY, 42)
    p2 = M.init_seq2seq(TINY, 42)
    p3 = M.init_seq2seq(TINY, 43)
    np.testing.assert_array_equal(np.asarray(p1["src_emb"]), np.asarray(p2["src_emb"]))
    assert not np.array_equal(np.asarray(p1["src_emb"]), np.asarray(p3["src_emb"]))


def test_encode_shape(params):
    rng = np.random.default_rng(0)
    src, _, _ = make_batch(TINY, rng)
    enc = M.encode(params, TINY, src, FP32)
    assert enc.shape == (8, 16, 32)
    assert np.isfinite(np.asarray(enc)).all()


def test_logits_shape(params):
    rng = np.random.default_rng(0)
    src, tgt_in, _ = make_batch(TINY, rng)
    enc = M.encode(params, TINY, src, FP32)
    logits = M.decode_states(params, TINY, enc, src, tgt_in, FP32)
    assert logits.shape == (8, 16, 64)


def test_smoothed_ce_ignores_pad():
    logits = jnp.zeros((2, 3, 8), jnp.float32)
    tgt = jnp.array([[3, 4, 0], [0, 0, 0]], jnp.int32)
    loss_sum, ntok = M.smoothed_ce(logits, tgt, 8)
    assert float(ntok) == 2.0
    assert float(loss_sum) > 0.0


def _train(cfg, qcfg, steps, lr=3e-3, seed=0, nbatches=4):
    """Train on a small fixed batch pool (memorization = trainability)."""
    p = M.init_seq2seq(cfg, seed)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    rng = np.random.default_rng(seed)
    batches = [make_batch(cfg, rng) for _ in range(nbatches)]
    fn = jax.jit(functools.partial(M.nmt_train_step, cfg=cfg))
    losses = []
    for i in range(1, steps + 1):
        src, tgt_in, tgt_out = batches[i % nbatches]
        p, m, v, loss = fn(p, m, v, float(i), src, tgt_in, tgt_out, qcfg, lr)
        losses.append(float(loss))
    return p, losses


def test_fp32_training_decreases_loss():
    _, losses = _train(TINY, FP32, 60)
    assert losses[-1] < losses[0] - 1.0
    assert all(np.isfinite(losses))


def test_dsq_aggressive_training_still_learns():
    """Paper Table 4: [2,2,2,16] BFP still trains at the start (slower,
    but the loss moves down rather than diverging)."""
    _, losses = _train(TINY, DSQ_AGGR, 60)
    assert losses[-1] < losses[0] - 0.05
    assert all(np.isfinite(losses))


def test_dsq_vs_fp32_losses_comparable():
    _, l_fp = _train(TINY, FP32, 40)
    _, l_q = _train(TINY, jnp.array([2.0, 16.0, 2.0, 4.0, 2.0, 4.0, 2.0, 16.0], jnp.float32), 40)
    # Stashing(BFP) [16,4,4,16] tracks fp32 closely (paper Table 1).
    assert abs(l_q[-1] - l_fp[-1]) < 0.6


def test_eval_step_counts(params):
    rng = np.random.default_rng(1)
    src, tgt_in, tgt_out = make_batch(TINY, rng)
    loss_sum, ncorrect, ntok = M.nmt_eval_step(params, src, tgt_in, tgt_out, TINY)
    assert float(ntok) == float((tgt_out != 0).sum())
    assert 0.0 <= float(ncorrect) <= float(ntok)
    assert np.isfinite(float(loss_sum))


def test_greedy_decode_shape_and_range(params):
    rng = np.random.default_rng(2)
    src, _, _ = make_batch(TINY, rng)
    toks = np.asarray(M.nmt_greedy_decode(params, src, TINY))
    assert toks.shape == (8, 16)
    assert toks[:, 0].tolist() == [M.BOS] * 8
    assert ((toks >= 0) & (toks < TINY.vocab)).all()


# ----------------------------------------------------------- classifier


def make_cls_batch(cfg, rng):
    """Separable rule: label = bucket of the count of 'marker' token 3."""
    toks = rng.integers(4, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    labels = rng.integers(0, cfg.nclasses, cfg.batch).astype(np.int32)
    for i, lab in enumerate(labels):
        toks[i, : 2 * lab + 1] = 3
    return toks, labels


def test_classifier_logits_shape():
    p = M.init_classifier(CTINY, 0)
    toks, _ = make_cls_batch(CTINY, np.random.default_rng(0))
    logits = M.classifier_logits(p, CTINY, toks, FP32)
    assert logits.shape == (8, 3)


def test_classifier_trains():
    p = M.init_classifier(CTINY, 0)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    rng = np.random.default_rng(0)
    fn = jax.jit(functools.partial(M.cls_train_step, cfg=CTINY))
    stash = jnp.array([2.0, 16.0, 2.0, 4.0, 2.0, 4.0, 2.0, 16.0], jnp.float32)  # Stashing(BFP)
    batches = [make_cls_batch(CTINY, rng) for _ in range(4)]
    first = last = None
    for i in range(1, 81):
        toks, labels = batches[i % 4]
        p, m, v, loss = fn(p, m, v, float(i), toks, labels, stash, 3e-3)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first - 0.2

    toks, labels = batches[0]
    loss, ncorrect, n = M.cls_eval_step(p, toks, labels, CTINY)
    assert float(n) == 8.0
    assert float(ncorrect) >= 5.0  # well above 1/3 chance


def test_adam_bias_correction_first_step():
    from compile import adam

    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 0.5, jnp.float32)}
    m, v = adam.init_state(p)
    p2, m2, v2 = adam.update(p, g, m, v, jnp.float32(1.0), jnp.float32(0.1))
    # After bias correction, first step ~= -lr * sign(g).
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.1, rtol=1e-4)
    assert np.allclose(np.asarray(m2["w"]), 0.05)
