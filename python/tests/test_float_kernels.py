"""L1 correctness for the float family (mode 4/5): pallas kernel vs the
jnp oracle, plus grid semantics pinned against numpy's own float16 /
ml_dtypes' bfloat16 rounding where the formats coincide.

Deliberately hypothesis-free (unlike test_kernels.py) so the float
coverage runs in minimal environments too.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.floatq import float_quantize

RNG = np.random.default_rng(2024)

E4M3 = ref.float_code(4, 3)
E5M2 = ref.float_code(5, 2)
FP16 = ref.float_code(5, 10)
BF16 = ref.float_code(8, 7)


def rand(shape, scale_lo=-8, scale_hi=8):
    return (
        RNG.standard_normal(shape) * np.exp(RNG.uniform(scale_lo, scale_hi, shape))
    ).astype(np.float32)


@pytest.mark.parametrize("shape", [(1, 16), (4, 16), (3, 24), (8, 128), (2, 3, 40), (7,), (5, 1)])
@pytest.mark.parametrize("code", [E4M3, E5M2, FP16, BF16, ref.float_code(3, 4)])
def test_float_matches_ref(shape, code):
    x = rand(shape)
    got = np.asarray(float_quantize(x, code))
    want = np.asarray(ref.float_quantize_ref(x, code))
    np.testing.assert_array_equal(got, want)


def test_float_code_packing():
    assert E4M3 == 403.0
    assert E5M2 == 502.0
    assert FP16 == 510.0
    assert BF16 == 807.0


def test_e4m3_known_values():
    # bias 7: max = 240, min subnormal 2^-9; round-half-to-even.
    x = np.array([1.0, 1.3, 1.0625, 240.0, 300.0, -1e9, 2.0**-9, 2.0**-10, 0.0],
                 np.float32)
    q = np.asarray(ref.float_quantize_ref(x, E4M3))
    np.testing.assert_array_equal(
        q,
        np.array([1.0, 1.25, 1.0, 240.0, 240.0, -240.0, 2.0**-9, 0.0, 0.0], np.float32),
    )


def test_e5m2_saturation_and_subnormals():
    x = np.array([57344.0, 1e9, -1e9, 3.0, 2.0**-16], np.float32)
    q = np.asarray(ref.float_quantize_ref(x, E5M2))
    np.testing.assert_array_equal(
        q, np.array([57344.0, 57344.0, -57344.0, 3.0, 2.0**-16], np.float32)
    )


def test_e5m10_matches_numpy_float16_rounding():
    # e5m10 is IEEE binary16 with saturation instead of inf: inside the
    # finite range (away from the inf-rounding boundary) our grid must
    # agree with numpy's float16 cast exactly, subnormals included.
    x = rand((512,), -10, 4)
    x = np.clip(x, -60000.0, 60000.0).astype(np.float32)
    got = np.asarray(ref.float_quantize_ref(x, FP16))
    want = x.astype(np.float16).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_e8m7_matches_bfloat16_on_normals():
    # bf16 = e8m7 on the normal range (our grid deviates only in the
    # f32-subnormal-step regime below ~2^-119).
    x = rand((512,), -6, 6)
    got = np.asarray(ref.float_quantize_ref(x, BF16))
    want = np.asarray(jnp.asarray(x, jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(got, want)


def test_float_idempotent():
    x = rand((8, 64))
    for code in [E4M3, E5M2, FP16, BF16]:
        q1 = np.asarray(float_quantize(x, code))
        q2 = np.asarray(float_quantize(q1, code))
        np.testing.assert_array_equal(q1, q2)


def test_float_error_monotone_in_mantissa_bits():
    x = rand((4, 64), -3, 3)
    errs = []
    for m in range(1, 11):
        q = np.asarray(ref.float_quantize_ref(x, ref.float_code(5, m)))
        errs.append(np.abs(q - x).sum())
    for a, b in zip(errs, errs[1:]):
        assert b <= a * 1.0000001 + 1e-12, errs


def test_float_nan_inf_semantics():
    x = np.array([np.nan, np.inf, -np.inf, 0.0, 1.0], np.float32)
    q = np.asarray(ref.float_quantize_ref(x, E4M3))
    assert np.isnan(q[0])
    assert q[1] == 240.0 and q[2] == -240.0, "±inf saturate"
    assert q[3] == 0.0 and q[4] == 1.0
    # All-NaN tensors stay NaN (no amax reduction to poison).
    q = np.asarray(ref.float_quantize_ref(np.full((8,), np.nan, np.float32), E5M2))
    assert np.isnan(q).all()


def test_select_quantize_ref_modes():
    x = rand((4, 32))
    np.testing.assert_array_equal(
        np.asarray(ref.select_quantize_ref(x, 4.0, E4M3)),
        np.asarray(ref.float_quantize_ref(x, E4M3)),
    )
    # Mode 5 (float-sr) shares the float grid with nearest rounding.
    np.testing.assert_array_equal(
        np.asarray(ref.select_quantize_ref(x, 5.0, E4M3)),
        np.asarray(ref.float_quantize_ref(x, E4M3)),
    )
    np.testing.assert_array_equal(np.asarray(ref.select_quantize_ref(x, 0.0, E4M3)), x)
