"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Bit-equality is required for the quantizers (same ops, same order); the
fused qgemm is allclose against quantize-then-dot (different accumulation
order is allowed).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.bfp import bfp_quantize, pick_block_rows
from compile.kernels.fixed import fixed_quantize
from compile.kernels.floatq import float_quantize
from compile.kernels.qgemm import bfp_qgemm

RNG = np.random.default_rng(2023)


def rand(shape, scale_lo=-8, scale_hi=8):
    return (
        RNG.standard_normal(shape) * np.exp(RNG.uniform(scale_lo, scale_hi, shape))
    ).astype(np.float32)


# ---------------------------------------------------------------- BFP


@pytest.mark.parametrize("shape", [(1, 16), (4, 16), (3, 24), (8, 128), (2, 3, 40), (7,), (5, 1)])
@pytest.mark.parametrize("mbits", [2.0, 3.0, 4.0, 8.0, 12.0, 16.0, 24.0, 25.0, 32.0])
def test_bfp_matches_ref(shape, mbits):
    x = rand(shape)
    got = np.asarray(bfp_quantize(x, mbits))
    want = np.asarray(ref.bfp_quantize_ref(x, mbits))
    np.testing.assert_array_equal(got, want)


def test_bfp_passthrough_at_high_bits():
    x = rand((4, 32))
    np.testing.assert_array_equal(np.asarray(bfp_quantize(x, 25.0)), x)
    np.testing.assert_array_equal(np.asarray(bfp_quantize(x, 32.0)), x)


def test_bfp_idempotent():
    x = rand((8, 64))
    for m in [2.0, 4.0, 8.0, 16.0]:
        q1 = np.asarray(bfp_quantize(x, m))
        q2 = np.asarray(bfp_quantize(q1, m))
        np.testing.assert_array_equal(q1, q2)


def test_bfp_zero_box():
    x = np.zeros((2, 32), np.float32)
    np.testing.assert_array_equal(np.asarray(bfp_quantize(x, 4.0)), x)


def test_bfp_preserves_sign_and_scale():
    x = rand((16, 64))
    q = np.asarray(bfp_quantize(x, 8.0))
    # max relative error within a box is bounded by one quantization step
    # relative to the box max: step/|x| <= 2^(2-m) * box_amax/|x|; at the box
    # max itself the relative error is <= 2^(1-m).
    boxed_x = x.reshape(16, 4, 16)
    boxed_q = q.reshape(16, 4, 16)
    amax = np.abs(boxed_x).max(-1, keepdims=True)
    err = np.abs(boxed_q - boxed_x)
    assert (err <= amax * 2.0 ** (2 - 8.0) + 1e-30).all()


def test_bfp_respects_box_structure():
    # Two boxes with wildly different magnitudes: the small box must keep
    # resolution (per-box exponent), unlike per-tensor fixed point.
    x = np.concatenate(
        [np.full((1, 16), 1000.0, np.float32), np.full((1, 16), 0.001, np.float32)], axis=1
    )
    q = np.asarray(bfp_quantize(x, 4.0))
    assert abs(q[0, 20] - 0.001) / 0.001 < 0.25  # small box survives
    qf = np.asarray(fixed_quantize(x, 4.0))
    assert qf[0, 20] == 0.0  # per-tensor fixed point flushes it


def test_pick_block_rows_divides():
    for rows in [1, 2, 7, 24, 128, 384]:
        for cols in [16, 128, 4096]:
            br = pick_block_rows(rows, cols)
            assert rows % br == 0 and br >= 1
            assert br * cols * 8 <= 4 * 1024 * 1024 or br == 1


@settings(max_examples=60, deadline=None)
@given(
    rows=st.integers(1, 24),
    cols=st.integers(1, 80),
    mbits=st.sampled_from([2.0, 3.0, 5.0, 8.0, 13.0, 24.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bfp_hypothesis_sweep(rows, cols, mbits, seed):
    r = np.random.default_rng(seed)
    x = (r.standard_normal((rows, cols)) * np.exp(r.uniform(-20, 20, (rows, cols)))).astype(
        np.float32
    )
    got = np.asarray(bfp_quantize(x, mbits))
    want = np.asarray(ref.bfp_quantize_ref(x, mbits))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    vals=st.lists(
        st.floats(
            min_value=float(np.float32(-1e30)),
            max_value=float(np.float32(1e30)),
            allow_nan=False,
            allow_infinity=False,
            width=32,
        ),
        min_size=1,
        max_size=48,
    ),
    mbits=st.sampled_from([2.0, 4.0, 8.0, 16.0]),
)
def test_bfp_hypothesis_adversarial_values(vals, mbits):
    x = np.asarray(vals, np.float32).reshape(1, -1)
    got = np.asarray(bfp_quantize(x, mbits))
    want = np.asarray(ref.bfp_quantize_ref(x, mbits))
    np.testing.assert_array_equal(got, want)
    # quantization never inflates the box max beyond one step
    assert np.isfinite(got).all()


# ---------------------------------------------------------------- fixed


@pytest.mark.parametrize("shape", [(1, 16), (4, 32), (3, 24), (2, 3, 8)])
@pytest.mark.parametrize("bits", [4.0, 8.0, 16.0, 25.0])
def test_fixed_matches_ref(shape, bits):
    x = rand(shape, -4, 4)
    got = np.asarray(fixed_quantize(x, bits))
    want = np.asarray(ref.fixed_quantize_ref(x, bits))
    np.testing.assert_array_equal(got, want)


def test_fixed_idempotent():
    x = rand((8, 16), -2, 2)
    for b in [4.0, 8.0, 16.0]:
        q1 = np.asarray(fixed_quantize(x, b))
        q2 = np.asarray(fixed_quantize(q1, b))
        np.testing.assert_array_equal(q1, q2)


def test_fixed_zero_tensor():
    x = np.zeros((3, 16), np.float32)
    np.testing.assert_array_equal(np.asarray(fixed_quantize(x, 8.0)), x)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(1, 40),
    bits=st.sampled_from([2.0, 4.0, 8.0, 16.0, 24.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fixed_hypothesis_sweep(rows, cols, bits, seed):
    r = np.random.default_rng(seed)
    x = (r.standard_normal((rows, cols)) * np.exp(r.uniform(-12, 12, (rows, cols)))).astype(
        np.float32
    )
    got = np.asarray(fixed_quantize(x, bits))
    want = np.asarray(ref.fixed_quantize_ref(x, bits))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------- float


@settings(max_examples=60, deadline=None)
@given(
    rows=st.integers(1, 24),
    cols=st.integers(1, 80),
    code=st.sampled_from([403.0, 502.0, 510.0, 807.0, 304.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_float_hypothesis_sweep(rows, cols, code, seed):
    r = np.random.default_rng(seed)
    x = (r.standard_normal((rows, cols)) * np.exp(r.uniform(-20, 20, (rows, cols)))).astype(
        np.float32
    )
    got = np.asarray(float_quantize(x, code))
    want = np.asarray(ref.float_quantize_ref(x, code))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    vals=st.lists(
        st.floats(
            min_value=float(np.float32(-1e30)),
            max_value=float(np.float32(1e30)),
            allow_nan=False,
            allow_infinity=False,
            width=32,
        ),
        min_size=1,
        max_size=48,
    ),
    code=st.sampled_from([403.0, 502.0, 510.0]),
)
def test_float_hypothesis_adversarial_values(vals, code):
    x = np.asarray(vals, np.float32).reshape(1, -1)
    got = np.asarray(float_quantize(x, code))
    want = np.asarray(ref.float_quantize_ref(x, code))
    np.testing.assert_array_equal(got, want)
    assert np.isfinite(got).all()  # saturation: finite in, finite out


# ---------------------------------------------------------------- select


@pytest.mark.parametrize("mode,bits", [(0.0, 4.0), (1.0, 8.0), (2.0, 4.0)])
def test_select_quantize_modes(mode, bits):
    x = rand((4, 32))
    got = np.asarray(ref.select_quantize_ref(x, mode, bits))
    if mode == 0.0:
        np.testing.assert_array_equal(got, x)
    elif mode == 1.0:
        np.testing.assert_array_equal(got, np.asarray(ref.fixed_quantize_ref(x, bits)))
    else:
        np.testing.assert_array_equal(got, np.asarray(ref.bfp_quantize_ref(x, bits)))


# ---------------------------------------------------------------- qgemm


@pytest.mark.parametrize("mkn", [(8, 32, 8), (16, 128, 24), (64, 256, 64), (24, 48, 96)])
@pytest.mark.parametrize("bits", [(2.0, 2.0), (4.0, 4.0), (8.0, 16.0), (25.0, 25.0)])
def test_qgemm_matches_ref(mkn, bits):
    m, k, n = mkn
    bx, bw = bits
    x = rand((m, k), -4, 4)
    w = rand((k, n), -4, 4)
    got = np.asarray(bfp_qgemm(x, w, bx, bw))
    want = np.asarray(ref.qgemm_ref(x, w, 2.0, bx, bw))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5 * max(1.0, np.abs(want).max()))


def test_qgemm_tiling_invariance():
    # Tile-local quantization must equal whole-tensor quantization because
    # boxes never straddle K tiles.
    x = rand((32, 256), -3, 3)
    w = rand((256, 32), -3, 3)
    a = np.asarray(bfp_qgemm(x, w, 4.0, 4.0, bm=32, bn=32, bk=256))
    b = np.asarray(bfp_qgemm(x, w, 4.0, 4.0, bm=8, bn=8, bk=64))
    c = np.asarray(bfp_qgemm(x, w, 4.0, 4.0, bm=16, bn=16, bk=16))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-4)
    np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-4)


def test_qgemm_passthrough_is_plain_matmul():
    x = rand((16, 64), -2, 2)
    w = rand((64, 16), -2, 2)
    got = np.asarray(bfp_qgemm(x, w, 25.0, 25.0))
    np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 12),
    kboxes=st.integers(1, 6),
    n=st.integers(1, 12),
    bx=st.sampled_from([2.0, 4.0, 8.0]),
    bw=st.sampled_from([2.0, 4.0, 8.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qgemm_hypothesis_sweep(m, kboxes, n, bx, bw, seed):
    r = np.random.default_rng(seed)
    k = kboxes * 16
    x = r.standard_normal((m, k)).astype(np.float32)
    w = r.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(bfp_qgemm(x, w, bx, bw))
    want = np.asarray(ref.qgemm_ref(x, w, 2.0, bx, bw))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * max(1.0, np.abs(want).max()))
